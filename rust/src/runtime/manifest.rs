//! The artifact manifest contract between `python/compile/aot.py` and the
//! Rust runtime.

use std::collections::BTreeMap;
use std::path::Path;

use crate::error::{Error, Result};
use crate::util::json::Json;

/// Element type of an artifact input/output.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F64,
    I32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Dtype> {
        match s {
            "float64" => Ok(Dtype::F64),
            "int32" => Ok(Dtype::I32),
            other => Err(Error::Manifest(format!("unsupported dtype {other:?}"))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Dtype::F64 => "float64",
            Dtype::I32 => "int32",
        }
    }
}

/// One input or output tensor.
#[derive(Clone, Debug)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl IoSpec {
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// One AOT artifact.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
    /// Padded dims (d_pad, e_pad, q_pad, r_pad, b_pad, k_rel ... as
    /// emitted by aot.py).
    pub meta: BTreeMap<String, usize>,
}

impl ArtifactSpec {
    pub fn meta_dim(&self, key: &str) -> Result<usize> {
        self.meta
            .get(key)
            .copied()
            .ok_or_else(|| Error::Manifest(format!("{}: missing meta {key:?}", self.name)))
    }
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

fn io_specs(j: &Json, what: &str) -> Result<Vec<IoSpec>> {
    j.as_arr()
        .ok_or_else(|| Error::Manifest(format!("{what} not an array")))?
        .iter()
        .map(|io| {
            let name = io
                .req("name")?
                .as_str()
                .ok_or_else(|| Error::Manifest(format!("{what}: name")))?
                .to_string();
            let shape = io
                .req("shape")?
                .as_arr()
                .ok_or_else(|| Error::Manifest(format!("{what}: shape")))?
                .iter()
                .map(|d| {
                    d.as_usize()
                        .ok_or_else(|| Error::Manifest(format!("{what}: bad dim")))
                })
                .collect::<Result<Vec<usize>>>()?;
            let dtype = Dtype::parse(
                io.req("dtype")?
                    .as_str()
                    .ok_or_else(|| Error::Manifest(format!("{what}: dtype")))?,
            )?;
            Ok(IoSpec { name, shape, dtype })
        })
        .collect()
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text)?;
        let format = j.req("format")?.as_str().unwrap_or("");
        if format != "hlo-text" {
            return Err(Error::Manifest(format!("unsupported format {format:?}")));
        }
        let arts = j
            .req("artifacts")?
            .as_obj()
            .ok_or_else(|| Error::Manifest("artifacts not an object".into()))?;
        let mut artifacts = BTreeMap::new();
        for (name, a) in arts {
            let file = a
                .req("file")?
                .as_str()
                .ok_or_else(|| Error::Manifest("file".into()))?
                .to_string();
            let inputs = io_specs(a.req("inputs")?, "inputs")?;
            let outputs = io_specs(a.req("outputs")?, "outputs")?;
            let mut meta = BTreeMap::new();
            if let Some(m) = a.get("meta").and_then(Json::as_obj) {
                for (k, v) in m {
                    if let Some(n) = v.as_usize() {
                        meta.insert(k.clone(), n);
                    }
                }
            }
            artifacts.insert(
                name.clone(),
                ArtifactSpec { name: name.clone(), file, inputs, outputs, meta },
            );
        }
        Ok(Manifest { artifacts })
    }

    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json")).map_err(|e| {
            Error::Manifest(format!(
                "cannot read {}/manifest.json (run `make artifacts`): {e}",
                dir.display()
            ))
        })?;
        Manifest::parse(&text)
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| Error::Manifest(format!("unknown artifact {name:?}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"{
      "format": "hlo-text",
      "artifacts": {
        "bdeu_batch": {
          "file": "bdeu_batch.hlo.txt",
          "sha256": "abc",
          "inputs": [
            {"name": "counts", "shape": [64, 256, 16], "dtype": "float64"},
            {"name": "alpha_row", "shape": [64], "dtype": "float64"},
            {"name": "alpha_cell", "shape": [64], "dtype": "float64"}
          ],
          "outputs": [{"name": "scores", "shape": [64], "dtype": "float64"}],
          "meta": {"b_pad": 64, "q_pad": 256, "r_pad": 16}
        }
      }
    }"#;

    #[test]
    fn parses_specs() {
        let m = Manifest::parse(DOC).unwrap();
        let a = m.artifact("bdeu_batch").unwrap();
        assert_eq!(a.inputs.len(), 3);
        assert_eq!(a.inputs[0].len(), 64 * 256 * 16);
        assert_eq!(a.inputs[0].dtype, Dtype::F64);
        assert_eq!(a.meta_dim("q_pad").unwrap(), 256);
        assert!(a.meta_dim("nope").is_err());
        assert!(m.artifact("ghost").is_err());
    }

    #[test]
    fn rejects_bad_format() {
        assert!(Manifest::parse(r#"{"format": "proto", "artifacts": {}}"#).is_err());
        assert!(Manifest::parse("[]").is_err());
    }
}
