//! The PJRT runtime: loads the AOT-compiled XLA artifacts produced by
//! `python/compile/aot.py` (HLO text + `manifest.json`) and executes them
//! from the Rust hot path.  Python never runs here.
//!
//! - [`manifest`] — parses `artifacts/manifest.json` (shapes, dtypes,
//!   padded dims) with the in-tree JSON parser.
//! - [`client`]   — PJRT CPU client, artifact compilation, typed
//!   execution, and the high-level `bdeu_batch` / `mobius` /
//!   `family_score` entry points.
//! - [`batcher`]  — the score micro-batcher: packs many family count
//!   matrices into the artifact's fixed batch axis per PJRT dispatch,
//!   plus a threaded scoring service with a request channel (the PJRT
//!   client is not `Send`, so the service thread owns its own runtime).

pub mod batcher;
pub mod client;
pub mod manifest;

pub use batcher::{FamilyCounts, ScoreBatcher, ScoreService};
pub use client::Runtime;
pub use manifest::{ArtifactSpec, IoSpec, Manifest};

use std::path::PathBuf;

/// Default artifact directory: `$RELCOUNT_ARTIFACTS` or `./artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var_os("RELCOUNT_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}
