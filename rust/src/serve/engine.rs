//! The serving engine: one writer, many readers, epoch-versioned
//! publishes.
//!
//! [`ServeEngine`] owns the mutable [`MaintainedCounts`] (the *writer*)
//! and a shared [`SnapshotStore`].  Applying a [`DeltaBatch`] goes
//! through [`ServeEngine::apply_publish`]:
//!
//! 1. clone the last-good writer state,
//! 2. apply the batch to the clone (delta maintenance, sharded over the
//!    writer's worker pool exactly as in `relcount apply`),
//! 3. on success, freeze the clone into generation N+1 and publish it
//!    atomically; on failure, drop the clone — the writer still holds
//!    generation N and the store keeps serving it.
//!
//! This turns PR 3's "poison on mid-batch failure" semantics into
//! *publish-or-keep-serving*: the poison is confined to the discarded
//! clone, the failure is reported to the caller of `apply_publish`, and
//! readers never see it.  Readers dispatch batches of count requests
//! over a worker pool with [`serve_batch`] — each distinct family is
//! routed to one worker by cache-key hash (the coordinator's post-count
//! sharding) and results come back in request order.
//!
//! With a [`DataDir`] attached ([`ServeEngine::attach_persistence`])
//! the publish point also becomes the durability point: between a
//! successful apply and the atomic publish, the batch is appended to
//! the WAL and `fsync`ed with the post-apply cache digest.  A failed
//! apply never reaches the log; a failed append aborts the publish (the
//! old generation keeps serving); and every published epoch is durable
//! before any reader can observe it — so crash recovery (snapshot +
//! WAL-suffix replay, see [`crate::persist`]) always lands exactly on
//! the last published generation.

use std::path::PathBuf;
use std::sync::Arc;

use crate::coordinator::shard::shard_of;
use crate::coordinator::{pool, resolve_workers};
use crate::ct::cttable::CtTable;
use crate::db::catalog::Database;
use crate::delta::{DeltaBatch, DeltaReport, MaintainConfig, MaintainedCounts};
use crate::error::{Error, Result};
use crate::meta::rvar::RVar;
use crate::persist::{DataDir, WalWriter};
use crate::serve::snapshot::{Generation, SnapshotStore};
use crate::strategies::cache::CtCache;
use crate::strategies::traits::FamilyRequest;

/// Durability sidecar: the data directory, the open WAL append handle,
/// and the periodic-snapshot counter.
struct PersistState {
    dir: DataDir,
    /// Always `Some` between operations — taken transiently while a
    /// snapshot save prunes the log ([`WalWriter::prune_through`]
    /// consumes the handle) and restored before returning.
    wal: Option<WalWriter>,
    /// Snapshot every N published batches (0 = only on shutdown).
    every: u64,
    since_snapshot: u64,
}

/// Writer half of the serving layer (see the module docs).
pub struct ServeEngine {
    writer: MaintainedCounts,
    store: Arc<SnapshotStore>,
    persist: Option<PersistState>,
}

impl ServeEngine {
    /// Build the maintained caches and publish generation 0.
    pub fn build(db: Database, cfg: MaintainConfig) -> Result<ServeEngine> {
        let writer = MaintainedCounts::build(db, cfg)?;
        let store = Arc::new(SnapshotStore::new(writer.snapshot(0)?));
        Ok(ServeEngine { writer, store, persist: None })
    }

    /// Wrap an already-built maintained state (publishes it as
    /// generation 0).
    pub fn from_maintained(writer: MaintainedCounts) -> Result<ServeEngine> {
        Self::from_maintained_at(writer, 0)
    }

    /// Wrap a recovered maintained state, publishing it as generation
    /// `epoch` — the recovery path: epochs keep counting from where the
    /// pre-crash process stopped, so WAL epochs stay strictly
    /// increasing across restarts.
    pub fn from_maintained_at(writer: MaintainedCounts, epoch: u64) -> Result<ServeEngine> {
        let store = Arc::new(SnapshotStore::new(writer.snapshot(epoch)?));
        Ok(ServeEngine { writer, store, persist: None })
    }

    /// Attach a data directory: open (truncating any torn tail) the
    /// WAL for append, and write an initial snapshot if the directory
    /// has none — from here on every published batch is durable.
    /// `every` > 0 also snapshots after that many published batches.
    pub fn attach_persistence(&mut self, dir: DataDir, every: u64) -> Result<()> {
        let wal = WalWriter::open(&dir.wal_path())?;
        let mut state =
            PersistState { dir, wal: Some(wal), every, since_snapshot: 0 };
        if !state.dir.has_snapshots()? {
            state.dir.save_snapshot(&mut self.writer, self.store.epoch())?;
        }
        self.persist = Some(state);
        Ok(())
    }

    /// Whether a data directory is attached.
    pub fn is_durable(&self) -> bool {
        self.persist.is_some()
    }

    /// Reader handle: clone freely, hand to any thread.
    pub fn store(&self) -> Arc<SnapshotStore> {
        self.store.clone()
    }

    /// Epoch of the currently published generation.
    pub fn epoch(&self) -> u64 {
        self.store.epoch()
    }

    /// The writer's database (the next batch's churn is generated
    /// against this state).
    pub fn db(&self) -> &Database {
        self.writer.db()
    }

    /// Digest of the writer's resident caches (equals the published
    /// generation's digest whenever no publish is in flight).
    pub fn digest(&self) -> u64 {
        self.writer.digest()
    }

    /// Apply one batch off to the side and publish the result as the
    /// next generation.  On error the batch is discarded whole: the
    /// writer keeps the last-good state, the store keeps serving the
    /// current generation, and the error is returned to the caller —
    /// readers are never poisoned and never see a partial batch.
    ///
    /// With persistence attached the batch is WAL-appended (and
    /// `fsync`ed) with its post-apply digest *before* the publish: a
    /// failed apply never reaches the log, a failed append aborts the
    /// publish, and every epoch a reader can see is already durable.
    pub fn apply_publish(&mut self, batch: &DeltaBatch) -> Result<(u64, DeltaReport)> {
        let mut next = self.writer.clone();
        let report = next.apply(batch)?; // Err: `next` (poisoned) is dropped
        let epoch = self.store.epoch() + 1;
        let snapshot = next.snapshot(epoch)?;
        if let Some(p) = &mut self.persist {
            let wal = p.wal.as_mut().ok_or_else(|| Error::Persist {
                section: "wal".into(),
                msg: "append handle lost by a failed prune".into(),
            })?;
            wal.append(epoch, next.digest(), batch)?;
        }
        self.writer = next;
        self.store.publish(snapshot);
        let snapshot_due = match &mut self.persist {
            Some(p) => {
                p.since_snapshot += 1;
                p.every > 0 && p.since_snapshot >= p.every
            }
            None => false,
        };
        if snapshot_due {
            self.persist_snapshot()?;
        }
        Ok((epoch, report))
    }

    /// Write a full snapshot of the current generation to the attached
    /// data directory (no-op when none is attached).  Returns the
    /// snapshot path.  Called periodically from `apply_publish` and on
    /// graceful shutdown by the server loop.
    ///
    /// After a successful save the WAL is pruned to the **oldest
    /// retained** snapshot's epoch ([`DataDir::wal_prune_cutoff`]):
    /// records at or below it are folded into every snapshot recovery
    /// could start from, so the log stops growing without bound while
    /// snapshot-plus-suffix replay — including the fallback past a
    /// damaged newer snapshot — stays whole.
    pub fn persist_snapshot(&mut self) -> Result<Option<PathBuf>> {
        let Some(p) = &mut self.persist else { return Ok(None) };
        let path = p.dir.save_snapshot(&mut self.writer, self.store.epoch())?;
        p.since_snapshot = 0;
        if let (Some(cutoff), Some(wal)) =
            (p.dir.wal_prune_cutoff()?, p.wal.take())
        {
            match wal.prune_through(cutoff) {
                Ok(w) => p.wal = Some(w),
                Err(e) => {
                    // the rewrite is atomic, so a reopen sees either the
                    // old or the pruned log — restore the handle before
                    // surfacing the error
                    p.wal = Some(WalWriter::open(&p.dir.wal_path())?);
                    return Err(e);
                }
            }
        }
        Ok(Some(path))
    }
}

/// The worker that owns a family's cache key — the single routing
/// function behind the byte-identical-across-worker-counts contract.
/// Both [`serve_batch`] and the server's micro-batch dispatch go
/// through here, so the invariant (stable hash, independent of worker
/// count and request order) has one source.
pub(crate) fn shard_for_family(vars: &[RVar], ctx_pops: &[usize], workers: usize) -> usize {
    shard_of(&CtCache::key(vars, ctx_pops), workers.max(1))
}

/// Serve a batch of family-count requests from one generation across
/// `workers` threads.  Families are routed by cache-key hash (stable
/// across worker counts) and results return in request order, so the
/// response stream is bit-identical for every worker count.  Individual
/// request failures stay on their slot — one bad family does not fail
/// the batch.
pub fn serve_batch(
    gen: &Generation,
    reqs: &[FamilyRequest],
    workers: usize,
) -> Vec<Result<CtTable>> {
    let workers = resolve_workers(workers);
    let mut assignment: Vec<Vec<usize>> = vec![Vec::new(); workers.max(1)];
    for (i, r) in reqs.iter().enumerate() {
        assignment[shard_for_family(&r.vars, &r.ctx_pops, workers)].push(i);
    }
    pool::run_shards(reqs, &assignment, |_, r| {
        gen.ct_for_family(&r.vars, &r.ctx_pops)
    })
    .results
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::fixtures::university_db;
    use crate::delta::DeltaOp;

    fn family() -> Vec<RVar> {
        vec![
            RVar::RelInd { rel: 0 },
            RVar::RelAttr { rel: 0, attr: 1 },
            RVar::EntityAttr { et: 1, attr: 0 },
        ]
    }

    #[test]
    fn publish_advances_epoch_and_changes_counts() {
        let mut e = ServeEngine::build(university_db(), MaintainConfig::default())
            .unwrap();
        let store = e.store();
        let g0 = store.load();
        let before = g0.ct_for_family(&family(), &[0, 1]).unwrap();

        let batch = DeltaBatch::new(vec![DeltaOp::DeleteLink { rel: 0, from: 0, to: 0 }]);
        let (epoch, rep) = e.apply_publish(&batch).unwrap();
        assert_eq!(epoch, 1);
        assert_eq!(rep.link_deletes, 1);
        assert_eq!(store.epoch(), 1);

        // gen 0 still serves the pre-batch counts; gen 1 the post-batch
        let after = store.load().ct_for_family(&family(), &[0, 1]).unwrap();
        assert_eq!(
            g0.ct_for_family(&family(), &[0, 1]).unwrap().digest(),
            before.digest()
        );
        assert_ne!(after.digest(), before.digest());
        assert_eq!(store.load().digest(), e.digest());
    }

    #[test]
    fn failed_batch_keeps_last_good_generation_serving() {
        let mut e = ServeEngine::build(university_db(), MaintainConfig::default())
            .unwrap();
        let store = e.store();
        let good = store.load().ct_for_family(&family(), &[0, 1]).unwrap();

        // op 1 mutates, op 2 fails -> the whole batch must vanish
        let bad = DeltaBatch::new(vec![
            DeltaOp::InsertLink { rel: 0, from: 11, to: 0, values: vec![2, 1] },
            DeltaOp::DeleteLink { rel: 0, from: 11, to: 18 }, // absent pair
        ]);
        assert!(e.apply_publish(&bad).is_err());
        assert_eq!(store.epoch(), 0, "failed publish must not advance the epoch");
        let still = store.load().ct_for_family(&family(), &[0, 1]).unwrap();
        assert_eq!(still.digest(), good.digest());

        // and the writer is NOT poisoned: the next good batch applies
        let fine = DeltaBatch::new(vec![DeltaOp::DeleteLink { rel: 0, from: 0, to: 0 }]);
        let (epoch, _) = e.apply_publish(&fine).unwrap();
        assert_eq!(epoch, 1);
        assert_ne!(
            store.load().ct_for_family(&family(), &[0, 1]).unwrap().digest(),
            good.digest()
        );
    }

    #[test]
    fn attached_engine_logs_every_publish_and_snapshots_periodically() {
        let root = std::env::temp_dir().join(format!(
            "relcount-engine-persist-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&root);
        let dd = DataDir::open(&root).unwrap();
        let mut e =
            ServeEngine::build(university_db(), MaintainConfig::default()).unwrap();
        e.attach_persistence(dd, 2).unwrap();
        assert!(e.is_durable());
        // attach wrote the initial (epoch 0) snapshot
        let dd = DataDir::open(&root).unwrap();
        assert_eq!(dd.snapshot_epochs().unwrap(), vec![0]);

        for i in 0..3u64 {
            let b = crate::datagen::churn::churn_batch(e.db(), 0.05, 0xBEEF + i);
            e.apply_publish(&b).unwrap();
        }
        // every publish hit the WAL; the every=2 policy snapshotted at 2
        let recs = crate::persist::read_records(&dd.wal_path()).unwrap();
        assert_eq!(
            recs.iter().map(|r| r.epoch).collect::<Vec<_>>(),
            vec![1, 2, 3]
        );
        assert_eq!(recs.last().unwrap().digest, e.digest());
        assert_eq!(dd.snapshot_epochs().unwrap(), vec![0, 2]);

        // recovery from snapshot 2 + WAL record 3 lands on the writer
        let (r, epoch) = dd.recover(0).unwrap();
        assert_eq!(epoch, 3);
        assert_eq!(r.digest(), e.digest());
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn snapshot_saves_prune_the_wal_without_breaking_recovery() {
        let root = std::env::temp_dir().join(format!(
            "relcount-engine-prune-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&root);
        let dd = DataDir::open(&root).unwrap();
        let mut e =
            ServeEngine::build(university_db(), MaintainConfig::default()).unwrap();
        e.attach_persistence(dd, 1).unwrap(); // snapshot on every publish
        for i in 0..4u64 {
            let b = crate::datagen::churn::churn_batch(e.db(), 0.05, 0xFACE + i);
            e.apply_publish(&b).unwrap();
        }
        let dd = DataDir::open(&root).unwrap();
        // retention kept snapshots 3 and 4; each save pruned through the
        // OLDEST retained epoch, so the log holds only the suffix the
        // fallback snapshot still needs — not all four batches
        assert_eq!(dd.snapshot_epochs().unwrap(), vec![3, 4]);
        assert_eq!(
            crate::persist::read_records(&dd.wal_path())
                .unwrap()
                .iter()
                .map(|r| r.epoch)
                .collect::<Vec<_>>(),
            vec![4]
        );
        let (r, epoch) = dd.recover(0).unwrap();
        assert_eq!(epoch, 4);
        assert_eq!(r.digest(), e.digest());

        // damage the newest snapshot: the pruned log must still carry
        // recovery from the older retained snapshot to the same state
        let caches = dd.snapshot_dir(4).join("caches.bin");
        let mut bytes = std::fs::read(&caches).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x10;
        std::fs::write(&caches, &bytes).unwrap();
        let (r, epoch) = dd.recover(0).unwrap();
        assert_eq!(epoch, 4);
        assert_eq!(r.digest(), e.digest());
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn custom_retention_threads_through_persist_snapshot() {
        let root = std::env::temp_dir().join(format!(
            "relcount-engine-retain-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&root);
        // --snapshot-retain 3: the engine keeps three epochs on disk and
        // the WAL prune cutoff trails the oldest of them
        let dd = DataDir::with_retain(&root, 3).unwrap();
        let mut e =
            ServeEngine::build(university_db(), MaintainConfig::default()).unwrap();
        e.attach_persistence(dd, 1).unwrap(); // snapshot on every publish
        for i in 0..4u64 {
            let b = crate::datagen::churn::churn_batch(e.db(), 0.05, 0xABBA + i);
            e.apply_publish(&b).unwrap();
        }
        let dd = DataDir::open(&root).unwrap();
        assert_eq!(dd.snapshot_epochs().unwrap(), vec![2, 3, 4]);
        assert_eq!(
            crate::persist::read_records(&dd.wal_path())
                .unwrap()
                .iter()
                .map(|r| r.epoch)
                .collect::<Vec<_>>(),
            vec![3, 4]
        );
        let (r, epoch) = dd.recover(0).unwrap();
        assert_eq!(epoch, 4);
        assert_eq!(r.digest(), e.digest());
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn serve_batch_is_request_ordered_and_worker_count_invariant() {
        let e = ServeEngine::build(university_db(), MaintainConfig::default()).unwrap();
        let g = e.store().load();
        let reqs = vec![
            FamilyRequest::new(&family(), &[0, 1]),
            FamilyRequest::new(
                &[RVar::RelInd { rel: 1 }, RVar::EntityAttr { et: 2, attr: 0 }],
                &[1, 2],
            ),
            FamilyRequest::new(&family(), &[0, 1]), // duplicate
        ];
        let one: Vec<u64> = serve_batch(&g, &reqs, 1)
            .into_iter()
            .map(|r| r.unwrap().digest())
            .collect();
        let four: Vec<u64> = serve_batch(&g, &reqs, 4)
            .into_iter()
            .map(|r| r.unwrap().digest())
            .collect();
        assert_eq!(one, four);
        assert_eq!(one[0], one[2]);
        assert_ne!(one[0], one[1]);
    }
}
