//! Snapshot-isolated concurrent count serving (`relcount serve`).
//!
//! The paper frames counting as the inner loop of model discovery, but
//! the systems it builds on (FACTORBASE, the MySQL virtual data mart)
//! are long-lived *services* over a live database.  This module turns
//! the counting engine into one:
//!
//! - [`snapshot`] — [`Generation`]: an immutable, epoch-stamped freeze
//!   of the maintained caches that answers `ct` queries from `&self`
//!   (no locks, no coordination), and [`SnapshotStore`]: the
//!   atomic-swap publish point readers load generations from;
//! - [`engine`] — [`ServeEngine`]: the single writer.  Delta batches
//!   apply to a private clone of the last-good state and publish as
//!   generation N+1; a mid-batch failure is reported on publish while
//!   generation N keeps serving (PR 3's poison never reaches readers);
//! - [`protocol`] — the line-delimited JSON wire format (count / score
//!   / stats / shutdown), with sorted rows and per-response content
//!   digests so answers are byte-comparable across runs and worker
//!   counts;
//! - [`server`] — the threaded front-end: a request pump, a
//!   micro-batching dispatch loop over the reader pool (one generation
//!   load per batch — a batch never straddles a publish), and the
//!   concurrent delta writer, on stdin or a TCP listener.  TCP mode is
//!   a readiness-polled non-blocking event loop: many sessions on one
//!   thread, per-session buffers, one session's failure isolated from
//!   the rest;
//! - [`shard`] / [`router`] — scale-out: `relcount shard` processes
//!   answer `pcount`/`pmarginal` with entity-hash partial tables, and
//!   `relcount route` merges the digest-checked partials (positives
//!   sum; the Möbius/negative completion runs once at the router) into
//!   responses byte-identical to single-process serving;
//! - [`replicate`] — generation replication: a leader streams its
//!   epoch-stamped publish log to followers, which independently
//!   apply-publish each batch and hard-check the resulting digest
//!   (divergence stops consumption and marks the follower unhealthy).
//!
//! The correctness contract extends the delta subsystem's differential
//! one: every answer a reader ever observes is bit-identical to a
//! from-scratch strategy on the database of the *exact generation
//! stamped on the response* — never a blend of adjacent generations —
//! and the response stream for a fixed input is byte-identical for
//! every `--workers` count (`rust/tests/delta_equivalence.rs`,
//! `rust/tests/serve_protocol.rs`).  Throughput, latency and queue
//! depth are reported per generation (`relcount exp serve`,
//! `benches/serve_throughput.rs`, EXPERIMENTS.md §E12).

pub mod engine;
pub mod protocol;
pub mod replicate;
pub mod router;
pub mod server;
pub mod shard;
pub mod snapshot;

pub use engine::{serve_batch, ServeEngine};
pub use protocol::{enumerate_requests, ServeRequest};
pub use replicate::{ReplHandle, ReplLog, Replicator};
pub use router::{run_router, Router, RouterSummary};
pub use server::{
    parse_delta_stream, run_serve, serve_listener, DeltaFeed, ServeOptions,
    ServeSummary,
};
pub use shard::ShardConfig;
pub use snapshot::{Generation, SnapshotStore};
