//! The line-delimited JSON wire format of `relcount serve`.
//!
//! One request per input line, one response per output line, responses
//! in request order.  Three operations:
//!
//! ```json
//! {"id": 0, "op": "count", "vars": [{"var": "rel_ind", "rel": 0},
//!  {"var": "entity_attr", "et": 1, "attr": 0}], "ctx": [0, 1]}
//! {"id": 1, "op": "score", "vars": [...], "ctx": [0, 1],
//!  "child": {"var": "entity_attr", "et": 1, "attr": 0}, "n_prime": 1.0}
//! {"id": 2, "op": "stats"}
//! ```
//!
//! A count response carries the full sorted table plus its
//! [`CtTable::digest`] and the epoch it was served from, so clients can
//! check snapshot consistency without shipping tables around:
//!
//! ```json
//! {"digest": "89abcdef01234567", "epoch": 3, "id": 0, "ok": true,
//!  "op": "count", "rows": [[0, 1, 5], ...], "total": 120}
//! ```
//!
//! Rows are `[value codes..., count]`, sorted ascending, and object
//! keys serialize in fixed (BTreeMap) order — so the response stream
//! for a fixed input is **byte-identical across worker counts** (the
//! serve smoke in CI diffs them).  Counts are exact `i128` internally;
//! the JSON carries them as numbers (exact up to 2^53) *and* under the
//! digest, which hashes the exact values.
//!
//! A failed request answers `{"error": "...", "id": N, "ok": false}` on
//! its own line; the session keeps going.

use crate::ct::cttable::CtTable;
use crate::db::catalog::Database;
use crate::error::{Error, Result};
use crate::lattice::Lattice;
use crate::meta::rvar::RVar;
use crate::util::json::Json;

/// One parsed request line.
#[derive(Clone, Debug, PartialEq)]
pub enum ServeRequest {
    /// Complete ct-table of a family in a population context.
    Count { id: u64, vars: Vec<RVar>, ctx: Vec<usize> },
    /// BDeu family score (`child` must be among `vars`).
    Score { id: u64, vars: Vec<RVar>, ctx: Vec<usize>, child: RVar, n_prime: f64 },
    /// Server/generation introspection.
    Stats { id: u64 },
    /// Ask the server to stop accepting sessions (TCP mode; on stdin
    /// the session simply ends at input EOF).
    Shutdown { id: u64 },
    /// Shard-internal: the partial positive table of one chain (only
    /// the join rows whose anchor entity the shard owns).  Answered by
    /// `relcount shard` processes; the router merges the partials.
    PCount { id: u64, chain: Vec<usize>, vars: Vec<RVar> },
    /// Shard-internal: the partial entity GROUP-BY of one population
    /// (only the entities the shard owns).
    PMarginal { id: u64, et: usize, vars: Vec<RVar> },
}

impl ServeRequest {
    pub fn id(&self) -> u64 {
        match *self {
            ServeRequest::Count { id, .. }
            | ServeRequest::Score { id, .. }
            | ServeRequest::Stats { id }
            | ServeRequest::Shutdown { id }
            | ServeRequest::PCount { id, .. }
            | ServeRequest::PMarginal { id, .. } => id,
        }
    }

    /// Parse one request line.
    pub fn parse(line: &str) -> Result<ServeRequest> {
        let j = Json::parse(line)?;
        let id = j
            .req("id")?
            .as_usize()
            .ok_or_else(|| Error::Data("`id` must be a non-negative integer".into()))?
            as u64;
        let op = j
            .req("op")?
            .as_str()
            .ok_or_else(|| Error::Data("`op` must be a string".into()))?;
        match op {
            "count" => Ok(ServeRequest::Count {
                id,
                vars: vars_of(&j)?,
                ctx: ctx_of(&j)?,
            }),
            "score" => Ok(ServeRequest::Score {
                id,
                vars: vars_of(&j)?,
                ctx: ctx_of(&j)?,
                child: rvar_from_json(j.req("child")?)?,
                n_prime: j.get("n_prime").and_then(Json::as_f64).unwrap_or(1.0),
            }),
            "stats" => Ok(ServeRequest::Stats { id }),
            "shutdown" => Ok(ServeRequest::Shutdown { id }),
            "pcount" => Ok(ServeRequest::PCount {
                id,
                chain: usize_arr(&j, "chain")?,
                vars: vars_of(&j)?,
            }),
            "pmarginal" => Ok(ServeRequest::PMarginal {
                id,
                et: j
                    .req("et")?
                    .as_usize()
                    .ok_or_else(|| Error::Data("`et` must be an entity id".into()))?,
                vars: vars_of(&j)?,
            }),
            other => Err(Error::Data(format!(
                "unknown op {other:?} (count | score | stats | shutdown | \
                 pcount | pmarginal)"
            ))),
        }
    }

    /// Emit the wire form (used by `relcount gen-requests` and the
    /// serve bench to synthesize request files).
    pub fn to_json(&self) -> Json {
        match self {
            ServeRequest::Count { id, vars, ctx } => Json::obj(vec![
                ("id", Json::num(*id as f64)),
                ("op", Json::str("count")),
                ("vars", vars_to_json(vars)),
                ("ctx", usizes_to_json(ctx)),
            ]),
            ServeRequest::Score { id, vars, ctx, child, n_prime } => Json::obj(vec![
                ("id", Json::num(*id as f64)),
                ("op", Json::str("score")),
                ("vars", vars_to_json(vars)),
                ("ctx", usizes_to_json(ctx)),
                ("child", rvar_to_json(child)),
                ("n_prime", Json::num(*n_prime)),
            ]),
            ServeRequest::Stats { id } => Json::obj(vec![
                ("id", Json::num(*id as f64)),
                ("op", Json::str("stats")),
            ]),
            ServeRequest::Shutdown { id } => Json::obj(vec![
                ("id", Json::num(*id as f64)),
                ("op", Json::str("shutdown")),
            ]),
            ServeRequest::PCount { id, chain, vars } => Json::obj(vec![
                ("id", Json::num(*id as f64)),
                ("op", Json::str("pcount")),
                ("chain", usizes_to_json(chain)),
                ("vars", vars_to_json(vars)),
            ]),
            ServeRequest::PMarginal { id, et, vars } => Json::obj(vec![
                ("id", Json::num(*id as f64)),
                ("op", Json::str("pmarginal")),
                ("et", Json::num(*et as f64)),
                ("vars", vars_to_json(vars)),
            ]),
        }
    }
}

fn vars_of(j: &Json) -> Result<Vec<RVar>> {
    j.req("vars")?
        .as_arr()
        .ok_or_else(|| Error::Data("`vars` must be an array".into()))?
        .iter()
        .map(rvar_from_json)
        .collect()
}

fn ctx_of(j: &Json) -> Result<Vec<usize>> {
    usize_arr(j, "ctx")
}

fn usize_arr(j: &Json, key: &str) -> Result<Vec<usize>> {
    j.req(key)?
        .as_arr()
        .ok_or_else(|| Error::Data(format!("`{key}` must be an array")))?
        .iter()
        .map(|x| {
            x.as_usize().ok_or_else(|| {
                Error::Data(format!("`{key}` entries must be non-negative integers"))
            })
        })
        .collect()
}

/// Parse one first-order variable:
/// `{"var": "entity_attr", "et": E, "attr": A}` |
/// `{"var": "rel_attr", "rel": R, "attr": A}` |
/// `{"var": "rel_ind", "rel": R}`.
pub fn rvar_from_json(j: &Json) -> Result<RVar> {
    let kind = j
        .req("var")?
        .as_str()
        .ok_or_else(|| Error::Data("`var` must be a string".into()))?;
    let field = |key: &str| -> Result<usize> {
        j.req(key)?
            .as_usize()
            .ok_or_else(|| Error::Data(format!("`{key}` must be a non-negative integer")))
    };
    match kind {
        "entity_attr" => Ok(RVar::EntityAttr { et: field("et")?, attr: field("attr")? }),
        "rel_attr" => Ok(RVar::RelAttr { rel: field("rel")?, attr: field("attr")? }),
        "rel_ind" => Ok(RVar::RelInd { rel: field("rel")? }),
        other => Err(Error::Data(format!(
            "unknown var kind {other:?} (entity_attr | rel_attr | rel_ind)"
        ))),
    }
}

/// Emit one first-order variable in the wire form.
pub fn rvar_to_json(v: &RVar) -> Json {
    match *v {
        RVar::EntityAttr { et, attr } => Json::obj(vec![
            ("var", Json::str("entity_attr")),
            ("et", Json::num(et as f64)),
            ("attr", Json::num(attr as f64)),
        ]),
        RVar::RelAttr { rel, attr } => Json::obj(vec![
            ("var", Json::str("rel_attr")),
            ("rel", Json::num(rel as f64)),
            ("attr", Json::num(attr as f64)),
        ]),
        RVar::RelInd { rel } => Json::obj(vec![
            ("var", Json::str("rel_ind")),
            ("rel", Json::num(rel as f64)),
        ]),
    }
}

fn vars_to_json(vars: &[RVar]) -> Json {
    Json::Arr(vars.iter().map(rvar_to_json).collect())
}

fn usizes_to_json(xs: &[usize]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::num(x as f64)).collect())
}

/// Sorted `[value codes..., count]` rows plus their total, the shared
/// table payload of count and partial responses.  Counts are carried as
/// JSON numbers (exact up to 2^53); the digest hashes the exact `i128`
/// values, so a truncated count is detectable downstream.
fn rows_json(ct: &CtTable) -> (Json, i128) {
    let mut rows: Vec<(Vec<u32>, i128)> = ct.iter_rows().collect();
    rows.sort();
    let total: i128 = rows.iter().map(|&(_, c)| c).sum();
    let arr = Json::Arr(
        rows.into_iter()
            .map(|(vals, c)| {
                let mut row: Vec<Json> =
                    vals.into_iter().map(|v| Json::num(v as f64)).collect();
                row.push(Json::num(c as f64));
                Json::Arr(row)
            })
            .collect(),
    );
    (arr, total)
}

/// Successful count response: sorted rows, exact-content digest, epoch.
pub fn count_response(id: u64, epoch: u64, ct: &CtTable) -> Json {
    let (rows, total) = rows_json(ct);
    Json::obj(vec![
        ("digest", Json::str(format!("{:016x}", ct.digest()))),
        ("epoch", Json::num(epoch as f64)),
        ("id", Json::num(id as f64)),
        ("ok", Json::Bool(true)),
        ("op", Json::str("count")),
        ("rows", rows),
        ("total", Json::num(total as f64)),
    ])
}

/// Successful partial-count response from one shard: the shard's slice
/// of a positive table (or entity marginal), its exact-content digest,
/// the serving epoch, the shard coordinates, and the shard's generation
/// digest (`state`) — the router re-derives the table digest from the
/// reconstructed rows and cross-checks `epoch`/`state` across shards,
/// so wire corruption and divergent replicas both surface as typed
/// route errors instead of silently wrong merged counts.
pub fn partial_response(
    id: u64,
    epoch: u64,
    state: u64,
    index: usize,
    of: usize,
    ct: &CtTable,
) -> Json {
    let (rows, total) = rows_json(ct);
    Json::obj(vec![
        ("digest", Json::str(format!("{:016x}", ct.digest()))),
        ("epoch", Json::num(epoch as f64)),
        ("id", Json::num(id as f64)),
        ("of", Json::num(of as f64)),
        ("ok", Json::Bool(true)),
        ("op", Json::str("partial")),
        ("rows", rows),
        ("shard", Json::num(index as f64)),
        ("state", Json::str(format!("{state:016x}"))),
        ("total", Json::num(total as f64)),
    ])
}

/// Successful score response.
pub fn score_response(id: u64, epoch: u64, score: f64) -> Json {
    Json::obj(vec![
        ("epoch", Json::num(epoch as f64)),
        ("id", Json::num(id as f64)),
        ("ok", Json::Bool(true)),
        ("op", Json::str("score")),
        ("score", Json::num(score)),
    ])
}

/// Stats response for one generation.
pub fn stats_response(id: u64, epoch: u64, resident_bytes: usize, digest: u64) -> Json {
    stats_response_ext(id, epoch, resident_bytes, digest, Vec::new())
}

/// [`stats_response`] with role-specific fields appended (shard
/// coordinates on shards; leader/follower epochs, lag and health on
/// replicas).  Single-role servers emit no extra keys, so the plain
/// stats wire shape — and every byte-identity test over it — is
/// untouched.
pub fn stats_response_ext(
    id: u64,
    epoch: u64,
    resident_bytes: usize,
    digest: u64,
    extra: Vec<(&str, Json)>,
) -> Json {
    let mut fields = vec![
        ("digest", Json::str(format!("{digest:016x}"))),
        ("epoch", Json::num(epoch as f64)),
        ("id", Json::num(id as f64)),
        ("ok", Json::Bool(true)),
        ("op", Json::str("stats")),
        ("resident_bytes", Json::num(resident_bytes as f64)),
    ];
    fields.extend(extra);
    Json::obj(fields)
}

/// Shutdown acknowledgement.
pub fn shutdown_response(id: u64, epoch: u64) -> Json {
    Json::obj(vec![
        ("epoch", Json::num(epoch as f64)),
        ("id", Json::num(id as f64)),
        ("ok", Json::Bool(true)),
        ("op", Json::str("shutdown")),
    ])
}

/// Failure response (`id` 0 when the line didn't parse far enough to
/// carry one).
pub fn error_response(id: u64, err: &Error) -> Json {
    Json::obj(vec![
        ("error", Json::str(err.to_string())),
        ("id", Json::num(id as f64)),
        ("ok", Json::Bool(false)),
    ])
}

/// Deterministic request workload over a database: singleton and pair
/// families of every lattice point (the enumeration the differential
/// tests use), as count requests with every third family also scored
/// against its first variable.  `limit` caps the list; ids are
/// sequential from 0.
pub fn enumerate_requests(
    db: &Database,
    max_chain_length: usize,
    limit: usize,
) -> Result<Vec<ServeRequest>> {
    let lattice = Lattice::build(&db.schema, max_chain_length)?;
    let mut out = Vec::new();
    let mut fams: Vec<(Vec<RVar>, Vec<usize>)> = Vec::new();
    for p in &lattice.points {
        let vars = p.all_vars();
        for i in 0..vars.len() {
            fams.push((vec![vars[i]], p.pops.clone()));
            for j in (i + 1)..vars.len() {
                fams.push((vec![vars[i], vars[j]], p.pops.clone()));
            }
        }
    }
    for (n, (vars, ctx)) in fams.into_iter().take(limit).enumerate() {
        let id = out.len() as u64;
        if n % 3 == 2 {
            out.push(ServeRequest::Score {
                id,
                child: vars[0],
                vars,
                ctx,
                n_prime: 1.0,
            });
        } else {
            out.push(ServeRequest::Count { id, vars, ctx });
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::fixtures::{university_db, university_schema};

    #[test]
    fn request_roundtrip() {
        let reqs = vec![
            ServeRequest::Count {
                id: 0,
                vars: vec![
                    RVar::RelInd { rel: 0 },
                    RVar::EntityAttr { et: 1, attr: 0 },
                ],
                ctx: vec![0, 1],
            },
            ServeRequest::Score {
                id: 1,
                vars: vec![RVar::RelAttr { rel: 0, attr: 1 }],
                ctx: vec![0, 1],
                child: RVar::RelAttr { rel: 0, attr: 1 },
                n_prime: 2.0,
            },
            ServeRequest::Stats { id: 2 },
            ServeRequest::PCount {
                id: 3,
                chain: vec![0, 1],
                vars: vec![RVar::EntityAttr { et: 1, attr: 0 }],
            },
            ServeRequest::PMarginal {
                id: 4,
                et: 0,
                vars: vec![RVar::EntityAttr { et: 0, attr: 0 }],
            },
        ];
        for r in reqs {
            let line = r.to_json().dump();
            assert_eq!(ServeRequest::parse(&line).unwrap(), r, "{line}");
        }
    }

    #[test]
    fn partial_response_carries_shard_coordinates_and_state() {
        let s = university_schema();
        let mut t = CtTable::new(&s, vec![RVar::EntityAttr { et: 1, attr: 0 }]).unwrap();
        t.add(&[1], 4).unwrap();
        let j = partial_response(7, 3, 0xabcd, 1, 2, &t);
        let back = Json::parse(&j.dump()).unwrap();
        assert_eq!(back.get("op").unwrap().as_str(), Some("partial"));
        assert_eq!(back.get("shard").unwrap().as_f64(), Some(1.0));
        assert_eq!(back.get("of").unwrap().as_f64(), Some(2.0));
        assert_eq!(back.get("state").unwrap().as_str(), Some("000000000000abcd"));
        assert_eq!(back.get("total").unwrap().as_f64(), Some(4.0));
        assert_eq!(
            back.get("digest").unwrap().as_str(),
            Some(format!("{:016x}", t.digest()).as_str())
        );
    }

    #[test]
    fn extended_stats_appends_role_fields_without_reshaping_the_base() {
        let plain = stats_response(1, 2, 64, 9).dump();
        let ext = stats_response_ext(
            1,
            2,
            64,
            9,
            vec![("role", Json::str("follower")), ("lag", Json::num(3.0))],
        )
        .dump();
        assert_ne!(plain, ext);
        let back = Json::parse(&ext).unwrap();
        assert_eq!(back.get("role").unwrap().as_str(), Some("follower"));
        assert_eq!(back.get("lag").unwrap().as_f64(), Some(3.0));
        // no extra keys -> byte-identical to the plain response
        assert_eq!(stats_response_ext(1, 2, 64, 9, Vec::new()).dump(), plain);
    }

    #[test]
    fn malformed_requests_rejected() {
        assert!(ServeRequest::parse("not json").is_err());
        assert!(ServeRequest::parse(r#"{"op":"count"}"#).is_err()); // no id
        assert!(ServeRequest::parse(r#"{"id":1,"op":"drop"}"#).is_err());
        assert!(
            ServeRequest::parse(r#"{"id":1,"op":"count","vars":[{"var":"nope"}],"ctx":[]}"#)
                .is_err()
        );
        // score defaults n_prime to 1.0
        let r = ServeRequest::parse(
            r#"{"id":1,"op":"score","vars":[{"var":"rel_ind","rel":0}],"ctx":[0],
                "child":{"var":"rel_ind","rel":0}}"#,
        )
        .unwrap();
        match r {
            ServeRequest::Score { n_prime, .. } => assert_eq!(n_prime, 1.0),
            _ => panic!("expected score"),
        }
    }

    #[test]
    fn count_response_rows_are_sorted_and_digested() {
        let s = university_schema();
        let mut t = CtTable::new(&s, vec![RVar::EntityAttr { et: 1, attr: 0 }]).unwrap();
        t.add(&[2], 7).unwrap();
        t.add(&[0], 3).unwrap();
        let j = count_response(5, 9, &t);
        let text = j.dump();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.get("id").unwrap().as_f64(), Some(5.0));
        assert_eq!(back.get("epoch").unwrap().as_f64(), Some(9.0));
        assert_eq!(back.get("total").unwrap().as_f64(), Some(10.0));
        let rows = back.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows[0].as_arr().unwrap()[0].as_f64(), Some(0.0));
        assert_eq!(rows[1].as_arr().unwrap()[0].as_f64(), Some(2.0));
        assert_eq!(
            back.get("digest").unwrap().as_str(),
            Some(format!("{:016x}", t.digest()).as_str())
        );
    }

    #[test]
    fn enumerate_requests_is_deterministic_and_bounded() {
        let db = university_db();
        let a = enumerate_requests(&db, 3, 12).unwrap();
        let b = enumerate_requests(&db, 3, 12).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.len(), 12);
        assert!(a.iter().any(|r| matches!(r, ServeRequest::Score { .. })));
        assert!(a.iter().any(|r| matches!(r, ServeRequest::Count { .. })));
        // ids are the line numbers
        for (i, r) in a.iter().enumerate() {
            assert_eq!(r.id(), i as u64);
        }
    }
}
