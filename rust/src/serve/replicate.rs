//! Generation replication: a leader streams every published
//! [`DeltaBatch`] — epoch-stamped and digest-stamped — to followers,
//! which independently apply-publish the same batches and must land on
//! **bit-identical** generations (same epoch, same digest) or stop.
//!
//! The wire format wraps the existing `DeltaBatch` JSON array in an
//! envelope object, one per line, terminated by an explicit eof marker
//! (so a follower can tell a quiesced leader from a dead connection):
//!
//! ```json
//! {"digest": "89abcdef01234567", "epoch": 1, "ops": [ ... ]}
//! {"eof": true}
//! ```
//!
//! The leader side is an in-memory [`ReplLog`] the delta writer appends
//! to after each successful publish, plus a [`Replicator`] acceptor
//! that streams the log to any number of followers, each from record
//! zero — replication replays the *full* publish history, so a
//! follower that connects late still converges on the leader's exact
//! final digest.  The follower side ([`follow`]) is a [`DeltaFeed`]
//! variant: it drives the follower's own engine, so recovery,
//! persistence and serving compose unchanged.
//!
//! [`DeltaFeed`]: crate::serve::server::DeltaFeed

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::delta::DeltaBatch;
use crate::error::{Error, Result};
use crate::serve::engine::ServeEngine;
use crate::util::json::Json;

/// One published generation: the batch that produced it plus the
/// epoch/digest the leader observed after publishing.
#[derive(Clone, Debug)]
pub struct ReplRecord {
    pub epoch: u64,
    pub digest: u64,
    pub batch: DeltaBatch,
}

#[derive(Debug, Default)]
struct LogState {
    records: Vec<Arc<ReplRecord>>,
    closed: bool,
}

/// Append-only in-memory publish log shared between the delta writer
/// (appends, closes) and the acceptor's per-follower streamer threads
/// (poll for new records by index).
#[derive(Debug, Default)]
pub struct ReplLog {
    state: Mutex<LogState>,
}

impl ReplLog {
    pub fn new() -> ReplLog {
        ReplLog::default()
    }

    pub fn append(&self, rec: ReplRecord) {
        let mut s = self.state.lock().expect("repl log poisoned");
        debug_assert!(!s.closed, "append after close");
        s.records.push(Arc::new(rec));
    }

    /// Mark the stream complete: streamers emit the eof marker once
    /// they have drained every record.
    pub fn close(&self) {
        self.state.lock().expect("repl log poisoned").closed = true;
    }

    /// Records from `from` on, plus whether the log is closed (a
    /// streamer that sees `(empty, true)` is fully drained).
    pub fn read_from(&self, from: usize) -> (Vec<Arc<ReplRecord>>, bool) {
        let s = self.state.lock().expect("repl log poisoned");
        (s.records.get(from..).unwrap_or(&[]).to_vec(), s.closed)
    }

    pub fn len(&self) -> usize {
        self.state.lock().expect("repl log poisoned").records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Follower-side lag/health gauges, surfaced through the stats
/// response: the leader epoch most recently *seen* on the wire, the
/// epoch most recently *applied* locally, and a health bit that drops
/// on the first divergence or stream failure (and never recovers —
/// a diverged replica must be rebuilt, not trusted).
#[derive(Debug, Default)]
pub struct ReplHandle {
    leader_epoch: AtomicU64,
    applied_epoch: AtomicU64,
    unhealthy: AtomicBool,
}

impl ReplHandle {
    pub fn new() -> ReplHandle {
        ReplHandle::default()
    }

    pub fn leader_epoch(&self) -> u64 {
        self.leader_epoch.load(Ordering::Acquire)
    }

    pub fn applied_epoch(&self) -> u64 {
        self.applied_epoch.load(Ordering::Acquire)
    }

    /// Wire-observed leader epoch minus locally applied epoch.
    pub fn lag(&self) -> u64 {
        self.leader_epoch().saturating_sub(self.applied_epoch())
    }

    pub fn healthy(&self) -> bool {
        !self.unhealthy.load(Ordering::Acquire)
    }

    fn note_leader(&self, epoch: u64) {
        self.leader_epoch.store(epoch, Ordering::Release);
    }

    fn note_applied(&self, epoch: u64) {
        self.applied_epoch.store(epoch, Ordering::Release);
    }

    fn mark_unhealthy(&self) {
        self.unhealthy.store(true, Ordering::Release);
    }
}

/// Wire envelope of one record.
pub fn envelope_json(rec: &ReplRecord) -> Json {
    Json::obj(vec![
        ("digest", Json::str(format!("{:016x}", rec.digest))),
        ("epoch", Json::num(rec.epoch as f64)),
        ("ops", rec.batch.to_json()),
    ])
}

/// The stream terminator.
pub fn eof_json() -> Json {
    Json::obj(vec![("eof", Json::Bool(true))])
}

/// Parse one stream line: `Ok(None)` is the eof marker, `Ok(Some(..))`
/// one `(epoch, digest, batch)` record.
pub fn parse_envelope(line: &str) -> Result<Option<(u64, u64, DeltaBatch)>> {
    let j = Json::parse(line)?;
    if matches!(j.get("eof"), Some(Json::Bool(true))) {
        return Ok(None);
    }
    let epoch = j
        .req("epoch")?
        .as_usize()
        .ok_or_else(|| Error::Replicate("`epoch` must be an integer".into()))?
        as u64;
    let digest_hex = j
        .req("digest")?
        .as_str()
        .ok_or_else(|| Error::Replicate("`digest` must be a hex string".into()))?;
    let digest = u64::from_str_radix(digest_hex, 16)
        .map_err(|e| Error::Replicate(format!("bad digest {digest_hex:?}: {e}")))?;
    let ops = j.req("ops")?;
    let batch = DeltaBatch::parse_json(&ops.dump())
        .map_err(|e| Error::Replicate(format!("epoch {epoch} ops: {e}")))?;
    Ok(Some((epoch, digest, batch)))
}

/// Leader acceptor: accepts follower connections on `listener` (made
/// non-blocking) until [`Replicator::shutdown`], streaming the full log
/// and the eof marker to each.  One streamer thread per follower — the
/// follower count is operator-controlled and tiny, unlike client
/// sessions.
pub struct Replicator {
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
}

impl Replicator {
    pub fn spawn(listener: TcpListener, log: Arc<ReplLog>) -> Result<Replicator> {
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let accept = std::thread::spawn(move || {
            let mut streamers: Vec<JoinHandle<()>> = Vec::new();
            while !stop2.load(Ordering::Acquire) {
                match listener.accept() {
                    Ok((stream, _peer)) => {
                        let log = Arc::clone(&log);
                        streamers.push(std::thread::spawn(move || {
                            // a follower that drops mid-stream only ends
                            // its own streamer
                            let _ = stream_log(stream, &log);
                        }));
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(2));
                    }
                    Err(_) => break,
                }
            }
            for s in streamers {
                let _ = s.join();
            }
        });
        Ok(Replicator { stop, accept: Some(accept) })
    }

    /// Stop accepting and wait for in-flight streamers to finish (they
    /// terminate on their own once the log closes).
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

/// Stream every log record (then eof) to one follower, blocking writes.
fn stream_log(stream: TcpStream, log: &ReplLog) -> Result<()> {
    stream.set_nonblocking(false)?;
    let mut w = std::io::BufWriter::new(stream);
    let mut next = 0usize;
    loop {
        let (records, closed) = log.read_from(next);
        for rec in &records {
            writeln!(w, "{}", envelope_json(rec).dump())?;
        }
        next += records.len();
        w.flush()?;
        if closed && log.len() == next {
            writeln!(w, "{}", eof_json().dump())?;
            w.flush()?;
            return Ok(());
        }
        if records.is_empty() {
            std::thread::sleep(Duration::from_millis(2));
        }
    }
}

/// How long [`follow`] keeps retrying the initial connect (the leader
/// may still be binding its replication port when the follower starts).
const CONNECT_RETRIES: usize = 50;
const CONNECT_PAUSE: Duration = Duration::from_millis(100);

/// Follower side: consume the leader's stream at `addr`, apply-publish
/// every batch through the follower's own engine, and hard-check each
/// published `(epoch, digest)` against the leader's record — the
/// first mismatch (or stream error) marks the replica unhealthy and
/// stops consumption; a replica that cannot prove bit-identity must
/// not keep publishing.  Returns `(publishes, failures)` in the shape
/// the delta writer reports.
pub fn follow(
    addr: &str,
    engine: &mut ServeEngine,
    handle: Option<&ReplHandle>,
    pause: Duration,
) -> (u64, Vec<(usize, String)>) {
    let mut publishes = 0u64;
    let mut failures: Vec<(usize, String)> = Vec::new();
    let fail = |i: usize, msg: String, failures: &mut Vec<(usize, String)>| {
        if let Some(h) = handle {
            h.mark_unhealthy();
        }
        failures.push((i, msg));
    };
    let mut stream = None;
    for attempt in 0..CONNECT_RETRIES {
        match TcpStream::connect(addr) {
            Ok(s) => {
                stream = Some(s);
                break;
            }
            Err(e) => {
                if attempt + 1 == CONNECT_RETRIES {
                    fail(0, format!("connect {addr}: {e}"), &mut failures);
                    return (publishes, failures);
                }
                std::thread::sleep(CONNECT_PAUSE);
            }
        }
    }
    let stream = stream.expect("connected or returned");
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    let mut i = 0usize;
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => {
                // stream died before the eof marker: a crashed leader,
                // not a quiesced one
                fail(i, "leader stream ended without eof".into(), &mut failures);
                return (publishes, failures);
            }
            Ok(_) => {}
            Err(e) => {
                fail(i, format!("leader stream: {e}"), &mut failures);
                return (publishes, failures);
            }
        }
        if line.trim().is_empty() {
            continue;
        }
        let (epoch, digest, batch) = match parse_envelope(line.trim_end()) {
            Ok(Some(rec)) => rec,
            Ok(None) => return (publishes, failures), // clean eof
            Err(e) => {
                fail(i, e.to_string(), &mut failures);
                return (publishes, failures);
            }
        };
        if let Some(h) = handle {
            h.note_leader(epoch);
        }
        if let Err(e) = engine.apply_publish(&batch) {
            fail(i, format!("epoch {epoch}: {e}"), &mut failures);
            return (publishes, failures);
        }
        if engine.epoch() != epoch || engine.digest() != digest {
            fail(
                i,
                Error::Replicate(format!(
                    "diverged at epoch {epoch}: leader digest {digest:016x}, \
                     follower epoch {} digest {:016x}",
                    engine.epoch(),
                    engine.digest()
                ))
                .to_string(),
                &mut failures,
            );
            return (publishes, failures);
        }
        publishes += 1;
        if let Some(h) = handle {
            h.note_applied(epoch);
        }
        if !pause.is_zero() {
            std::thread::sleep(pause);
        }
        i += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::fixtures::university_db;
    use crate::datagen::churn::churn_batch;
    use crate::delta::MaintainConfig;

    fn engine() -> ServeEngine {
        ServeEngine::build(university_db(), MaintainConfig::default()).unwrap()
    }

    #[test]
    fn envelope_roundtrip_and_eof() {
        let batch = churn_batch(engine().db(), 0.1, 7);
        let rec = ReplRecord { epoch: 3, digest: 0xdead_beef, batch: batch.clone() };
        let line = envelope_json(&rec).dump();
        let (e, d, b) = parse_envelope(&line).unwrap().unwrap();
        assert_eq!((e, d), (3, 0xdead_beef));
        assert_eq!(b, batch);
        assert_eq!(parse_envelope(&eof_json().dump()).unwrap(), None);
        assert!(parse_envelope("{\"epoch\": 1}").is_err());
    }

    #[test]
    fn follower_replays_to_the_leader_digest() {
        // leader: publish two churn batches, logging each
        let log = Arc::new(ReplLog::new());
        let mut leader = engine();
        for i in 0..2u64 {
            let b = churn_batch(leader.db(), 0.2, 40 + i);
            leader.apply_publish(&b).unwrap();
            log.append(ReplRecord {
                epoch: leader.epoch(),
                digest: leader.digest(),
                batch: b,
            });
        }
        log.close();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let repl = Replicator::spawn(listener, Arc::clone(&log)).unwrap();

        let mut follower = engine();
        let handle = ReplHandle::new();
        let (publishes, failures) =
            follow(&addr, &mut follower, Some(&handle), Duration::ZERO);
        repl.shutdown();
        assert!(failures.is_empty(), "{failures:?}");
        assert_eq!(publishes, 2);
        assert_eq!(follower.epoch(), leader.epoch());
        assert_eq!(follower.digest(), leader.digest());
        assert!(handle.healthy());
        assert_eq!(handle.lag(), 0);
        assert_eq!(handle.applied_epoch(), 2);
    }

    #[test]
    fn diverged_follower_goes_unhealthy_and_stops() {
        let log = Arc::new(ReplLog::new());
        let mut leader = engine();
        let b = churn_batch(leader.db(), 0.2, 9);
        leader.apply_publish(&b).unwrap();
        log.append(ReplRecord {
            epoch: leader.epoch(),
            // corrupt digest: the follower must refuse to accept it
            digest: leader.digest() ^ 1,
            batch: b,
        });
        log.close();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let repl = Replicator::spawn(listener, Arc::clone(&log)).unwrap();

        let mut follower = engine();
        let handle = ReplHandle::new();
        let (publishes, failures) =
            follow(&addr, &mut follower, Some(&handle), Duration::ZERO);
        repl.shutdown();
        assert_eq!(publishes, 0);
        assert_eq!(failures.len(), 1);
        assert!(failures[0].1.contains("diverged"), "{failures:?}");
        assert!(!handle.healthy());
    }
}
