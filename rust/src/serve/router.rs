//! Router role of scale-out serving: `relcount route` owns no counts of
//! its own — it fans every positive-table need of a request out to the
//! shard set as `pcount`/`pmarginal` partials, merges them, and runs the
//! Möbius/negative completion **once** at the router.
//!
//! Exactness rests on three checks per fan-out (DESIGN.md §3i):
//!
//! - **partition** — anchor-entity ownership partitions a chain's join
//!   rows, so the shard partials *sum* to the full positive table
//!   integer-exactly (no row is counted twice, none is dropped);
//! - **wire integrity** — the router re-derives each partial's content
//!   digest from the reconstructed rows and compares it with the digest
//!   the shard computed over its exact `i128` counts, so a corrupted or
//!   lossy wire row (counts travel as JSON numbers, exact to 2^53) is a
//!   typed [`Error::Route`], never a silently wrong merge;
//! - **pinning** — the first partial of a request pins `(epoch, state
//!   digest)`; every later partial of the *same request* must match, so
//!   shards that diverged (or straddled a publish mid-request) surface
//!   as a typed route error instead of a blended answer.
//!
//! With the checks green, the merged positive tables equal the
//! single-process ones row for row, the completion is the same code
//! path, and the routed `count`/`score` responses are **byte-identical**
//! to `relcount serve` on the unsharded database — the equivalence CI
//! lane (`scripts/scaleout_smoke.sh`) and
//! `rust/tests/scaleout_equivalence.rs` hold it to that.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::time::{Duration, Instant};

use crate::ct::cttable::CtTable;
use crate::ct::mobius::{mobius_complete, ChainSource};
use crate::db::catalog::Database;
use crate::db::schema::Schema;
use crate::error::{Error, Result};
use crate::learn::score::bdeu_from_ct;
use crate::metrics::report::ServeRow;
use crate::meta::rvar::RVar;
use crate::serve::protocol::{
    count_response, error_response, score_response, shutdown_response,
    stats_response_ext, ServeRequest,
};
use crate::serve::server::{event_loop, Envelope, ServeCounters, ServeOptions};
use crate::util::json::Json;

/// One persistent line-protocol connection to a shard, with one
/// transparent reconnect per request — enough for a shard that was
/// killed and restarted from its data directory to rejoin the topology
/// without bouncing the router.
pub struct ShardConn {
    addr: String,
    wire: Option<Wire>,
}

struct Wire {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl ShardConn {
    pub fn new(addr: impl Into<String>) -> ShardConn {
        ShardConn { addr: addr.into(), wire: None }
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// One request/response exchange.  An I/O failure drops the cached
    /// connection and retries once on a fresh connect; a second failure
    /// — or an in-protocol `ok: false` from the shard — becomes a typed
    /// [`Error::Route`] naming the shard.
    pub fn request(&mut self, req: &ServeRequest) -> Result<Json> {
        let line = req.to_json().dump();
        let mut last_io = None;
        for _ in 0..2 {
            match self.try_exchange(&line) {
                Ok(resp) => {
                    if resp.get("ok") == Some(&Json::Bool(true)) {
                        return Ok(resp);
                    }
                    let msg = resp
                        .get("error")
                        .and_then(Json::as_str)
                        .unwrap_or("malformed error response");
                    return Err(Error::Route(format!("shard {}: {msg}", self.addr)));
                }
                Err(e) => {
                    self.wire = None;
                    last_io = Some(e);
                }
            }
        }
        let e = last_io.expect("two attempts always set last_io on failure");
        Err(Error::Route(format!("shard {}: {e}", self.addr)))
    }

    fn try_exchange(&mut self, line: &str) -> std::io::Result<Json> {
        if self.wire.is_none() {
            let writer = TcpStream::connect(&self.addr)?;
            let reader = BufReader::new(writer.try_clone()?);
            self.wire = Some(Wire { writer, reader });
        }
        let w = self.wire.as_mut().expect("wire just ensured");
        w.writer.write_all(line.as_bytes())?;
        w.writer.write_all(b"\n")?;
        let mut resp = String::new();
        if w.reader.read_line(&mut resp)? == 0 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "shard closed the connection",
            ));
        }
        Json::parse(resp.trim_end()).map_err(|e| {
            std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string())
        })
    }
}

/// Numeric field of a shard response, or a typed route error naming it.
fn field_u64(resp: &Json, key: &str, addr: &str) -> Result<u64> {
    resp.get(key)
        .and_then(Json::as_f64)
        .map(|v| v as u64)
        .ok_or_else(|| Error::Route(format!("shard {addr}: response lacks {key}")))
}

/// Hex-string digest field of a shard response.
fn field_hex(resp: &Json, key: &str, addr: &str) -> Result<u64> {
    resp.get(key)
        .and_then(Json::as_str)
        .and_then(|s| u64::from_str_radix(s, 16).ok())
        .ok_or_else(|| {
            Error::Route(format!("shard {addr}: response lacks hex {key}"))
        })
}

/// The [`ChainSource`] of one routed request: every positive chain table
/// and entity marginal the Möbius completion asks for is fanned out to
/// the shard set and merged under the integrity checks of the module
/// docs.  Lives for exactly one request — the pin must not outlast it.
struct RouterSource<'a> {
    db: &'a Database,
    conns: &'a mut [ShardConn],
    next_id: &'a mut u64,
    /// `(epoch, state digest)` pinned by the first partial answered.
    pin: Option<(u64, u64)>,
    /// Marginals repeat across the subsets of one completion; one
    /// fan-out each per request is enough.
    marginals: BTreeMap<(usize, Vec<RVar>), CtTable>,
    /// Wall time spent reconstructing and merging partials (the
    /// router-side overhead the bench reports).
    merge_wall: Duration,
}

/// Pin or cross-check the `(epoch, state)` a shard answered at.
fn pin_check(
    pin: &mut Option<(u64, u64)>,
    addr: &str,
    epoch: u64,
    state: u64,
) -> Result<()> {
    match *pin {
        None => {
            *pin = Some((epoch, state));
            Ok(())
        }
        Some((pe, ps)) if pe != epoch || ps != state => Err(Error::Route(format!(
            "shards diverged: {addr} answered at epoch {epoch} state \
             {state:016x}, but this request is pinned to epoch {pe} \
             state {ps:016x}"
        ))),
        Some(_) => Ok(()),
    }
}

/// Validate one shard's partial response and fold its rows into the
/// accumulator (the integrity checks of the module docs).  `slice` is
/// the `(index, of)` coordinates the router expects the shard to own.
fn merge_partial(
    schema: &Schema,
    pin: &mut Option<(u64, u64)>,
    resp: &Json,
    addr: &str,
    slice: (usize, usize),
    vars: &[RVar],
    acc: &mut CtTable,
) -> Result<()> {
    if resp.get("op").and_then(Json::as_str) != Some("partial") {
        return Err(Error::Route(format!(
            "shard {addr}: expected a partial response"
        )));
    }
    let shard = field_u64(resp, "shard", addr)? as usize;
    let claimed_of = field_u64(resp, "of", addr)? as usize;
    if (shard, claimed_of) != slice {
        return Err(Error::Route(format!(
            "shard {addr} answered as slice {shard}/{claimed_of}, expected \
             {}/{} — shard flags disagree with the router topology",
            slice.0, slice.1
        )));
    }
    let epoch = field_u64(resp, "epoch", addr)?;
    let state = field_hex(resp, "state", addr)?;
    pin_check(pin, addr, epoch, state)?;
    // Reconstruct the partial in its own table first: its digest must
    // reproduce the one the shard computed over exact counts.
    let mut part = CtTable::new(schema, vars.to_vec())?;
    let rows = resp
        .get("rows")
        .and_then(Json::as_arr)
        .ok_or_else(|| Error::Route(format!("shard {addr}: response lacks rows")))?;
    for row in rows {
        let cells = row.as_arr().unwrap_or(&[]);
        if cells.len() != vars.len() + 1 {
            return Err(Error::Route(format!(
                "shard {addr}: row arity {} != {}",
                cells.len(),
                vars.len() + 1
            )));
        }
        let mut vals = Vec::with_capacity(vars.len());
        for c in &cells[..vars.len()] {
            let v = c.as_f64().ok_or_else(|| {
                Error::Route(format!("shard {addr}: non-numeric row cell"))
            })?;
            vals.push(v as u32);
        }
        let count = cells[vars.len()].as_f64().ok_or_else(|| {
            Error::Route(format!("shard {addr}: non-numeric count"))
        })? as i128;
        part.add(&vals, count)?;
    }
    let claimed = field_hex(resp, "digest", addr)?;
    if part.digest() != claimed {
        return Err(Error::Route(format!(
            "shard {addr}: partial table digest mismatch (reconstructed \
             {:016x}, shard claimed {claimed:016x}) — wire corruption or \
             a count beyond exact JSON range",
            part.digest()
        )));
    }
    for (vals, c) in part.iter_rows() {
        acc.add(&vals, c)?;
    }
    Ok(())
}

impl<'a> RouterSource<'a> {
    fn new(
        db: &'a Database,
        conns: &'a mut [ShardConn],
        next_id: &'a mut u64,
    ) -> RouterSource<'a> {
        RouterSource {
            db,
            conns,
            next_id,
            pin: None,
            marginals: BTreeMap::new(),
            merge_wall: Duration::ZERO,
        }
    }

    /// Fan one partial request out to every shard and merge the partial
    /// tables (positives sum; the completion runs later, once, at the
    /// router).
    fn fan(
        &mut self,
        req_of: &dyn Fn(u64) -> ServeRequest,
        vars: &[RVar],
    ) -> Result<CtTable> {
        let of = self.conns.len();
        let mut acc = CtTable::new(&self.db.schema, vars.to_vec())?;
        for (index, conn) in self.conns.iter_mut().enumerate() {
            let id = *self.next_id;
            *self.next_id += 1;
            let addr = conn.addr().to_string();
            let resp = conn.request(&req_of(id))?;
            let t0 = Instant::now();
            merge_partial(
                &self.db.schema,
                &mut self.pin,
                &resp,
                &addr,
                (index, of),
                vars,
                &mut acc,
            )?;
            self.merge_wall += t0.elapsed();
        }
        Ok(acc)
    }

    /// Fan a stats request out and pin/cross-check the shard states;
    /// returns `(epoch, state digest, summed resident bytes)`.
    fn stats_fan(&mut self) -> Result<(u64, u64, usize)> {
        let mut resident = 0usize;
        for conn in self.conns.iter_mut() {
            let id = *self.next_id;
            *self.next_id += 1;
            let addr = conn.addr().to_string();
            let resp = conn.request(&ServeRequest::Stats { id })?;
            let epoch = field_u64(&resp, "epoch", &addr)?;
            let state = field_hex(&resp, "digest", &addr)?;
            pin_check(&mut self.pin, &addr, epoch, state)?;
            resident += field_u64(&resp, "resident_bytes", &addr)? as usize;
        }
        let (epoch, state) = self
            .pin
            .ok_or_else(|| Error::Route("router has no shards configured".into()))?;
        Ok((epoch, state, resident))
    }

    /// The `(epoch, state)` this request is pinned to, pinning off a
    /// stats fan-out if no partial was needed (a population-only count
    /// never touches a shard, but its response must still carry the
    /// topology's epoch).
    fn pinned(&mut self) -> Result<(u64, u64)> {
        if let Some(p) = self.pin {
            return Ok(p);
        }
        self.stats_fan()?;
        self.pin
            .ok_or_else(|| Error::Route("router has no shards configured".into()))
    }
}

impl ChainSource for RouterSource<'_> {
    fn positive_chain_ct(&mut self, chain: &[usize], vars: &[RVar]) -> Result<CtTable> {
        let chain = chain.to_vec();
        let vars_v = vars.to_vec();
        self.fan(
            &|id| ServeRequest::PCount {
                id,
                chain: chain.clone(),
                vars: vars_v.clone(),
            },
            vars,
        )
    }

    fn entity_marginal(&mut self, et: usize, vars: &[RVar]) -> Result<CtTable> {
        let key = (et, vars.to_vec());
        if let Some(hit) = self.marginals.get(&key) {
            return Ok(hit.clone());
        }
        let vars_v = vars.to_vec();
        let ct = self
            .fan(&|id| ServeRequest::PMarginal { id, et, vars: vars_v.clone() }, vars)?;
        self.marginals.insert(key, ct.clone());
        Ok(ct)
    }

    fn schema(&self) -> &Schema {
        &self.db.schema
    }

    fn population(&self, et: usize) -> i128 {
        self.db.population(et) as i128
    }
}

/// The request-answering half of `relcount route`: holds the shard
/// connections and the schema-bearing database (the router never counts
/// from it — it only needs populations and ct-table coordinates).
pub struct Router {
    db: Database,
    conns: Vec<ShardConn>,
    next_id: u64,
    /// Accumulated wall time spent merging partials, across requests.
    pub merge_wall: Duration,
    /// Last `(epoch, state)` any request pinned — stamps responses that
    /// need no fan-out of their own (shutdown) and the metric rows.
    epoch: u64,
}

impl Router {
    pub fn new(db: Database, shard_addrs: &[String]) -> Router {
        Router {
            db,
            conns: shard_addrs.iter().map(ShardConn::new).collect(),
            next_id: 0,
            merge_wall: Duration::ZERO,
            epoch: 0,
        }
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Answer one request end to end.  Every failure — unreachable
    /// shard, digest mismatch, divergence — is an in-protocol error
    /// response; the router session keeps serving.
    pub(crate) fn answer(&mut self, env: &Envelope) -> Json {
        match &env.req {
            Err(e) => error_response(0, e),
            Ok(req) => self.answer_req(req),
        }
    }

    fn answer_req(&mut self, req: &ServeRequest) -> Json {
        match req {
            ServeRequest::Count { id, vars, ctx } => {
                let mut src =
                    RouterSource::new(&self.db, &mut self.conns, &mut self.next_id);
                let out = mobius_complete(&mut src, vars, ctx)
                    .and_then(|ct| src.pinned().map(|p| (ct, p)));
                let resp = match out {
                    Ok((ct, (epoch, _))) => {
                        self.epoch = epoch;
                        count_response(*id, epoch, &ct)
                    }
                    Err(e) => error_response(*id, &e),
                };
                self.merge_wall += src.merge_wall;
                resp
            }
            ServeRequest::Score { id, vars, ctx, child, n_prime } => {
                // mirror `Generation::score_family` exactly (message
                // included) so routed and single-process responses stay
                // byte-identical
                if !vars.contains(child) {
                    return error_response(
                        *id,
                        &Error::Learn(format!(
                            "score child {child:?} is not among the family variables"
                        )),
                    );
                }
                let mut src =
                    RouterSource::new(&self.db, &mut self.conns, &mut self.next_id);
                let out = mobius_complete(&mut src, vars, ctx)
                    .and_then(|ct| src.pinned().map(|p| (ct, p)))
                    .and_then(|(ct, p)| {
                        bdeu_from_ct(&ct, child, *n_prime).map(|s| (s, p))
                    });
                let resp = match out {
                    Ok((s, (epoch, _))) => {
                        self.epoch = epoch;
                        score_response(*id, epoch, s)
                    }
                    Err(e) => error_response(*id, &e),
                };
                self.merge_wall += src.merge_wall;
                resp
            }
            ServeRequest::Stats { id } => {
                let shards = self.conns.len();
                let mut src =
                    RouterSource::new(&self.db, &mut self.conns, &mut self.next_id);
                match src.stats_fan() {
                    Ok((epoch, state, resident)) => {
                        self.epoch = epoch;
                        stats_response_ext(
                            *id,
                            epoch,
                            resident,
                            state,
                            vec![
                                ("role", Json::str("router")),
                                ("shards", Json::num(shards as f64)),
                            ],
                        )
                    }
                    Err(e) => error_response(*id, &e),
                }
            }
            ServeRequest::Shutdown { id } => shutdown_response(*id, self.epoch),
            ServeRequest::PCount { id, .. } | ServeRequest::PMarginal { id, .. } => {
                error_response(
                    *id,
                    &Error::Route(
                        "partial ops are shard-internal; ask the router for \
                         count or score"
                            .into(),
                    ),
                )
            }
        }
    }
}

/// Outcome of one router run.
#[derive(Clone, Debug)]
pub struct RouterSummary {
    /// Per-epoch latency/throughput rows (`shards`, `sessions` and
    /// `merge_overhead_s` filled in).
    pub rows: Vec<ServeRow>,
    pub requests: u64,
    pub errors: u64,
    pub sessions: u64,
    /// `(session id, error)` for client sessions that died mid-stream.
    pub session_failures: Vec<(u64, String)>,
    /// Total wall time spent reconstructing and merging shard partials.
    pub merge_wall: Duration,
    /// Last epoch the shard set was observed at.
    pub final_epoch: u64,
}

/// `relcount route`: accept clients on `listener` and answer each
/// request by fanning partials out to `shard_addrs` (the same
/// non-blocking multi-client [`event_loop`] as `relcount serve`).  Runs
/// until a client sends `{"op": "shutdown"}` — shards are independent
/// processes and keep running; the smoke topology shuts them down
/// directly.
pub fn run_router(
    db: Database,
    shard_addrs: &[String],
    listener: TcpListener,
    opts: &ServeOptions,
) -> Result<RouterSummary> {
    let shards = shard_addrs.len();
    if shards == 0 {
        return Err(Error::Route("router needs at least one shard address".into()));
    }
    let mut router = Router::new(db, shard_addrs);
    let mut acc = BTreeMap::new();
    let mut counters = ServeCounters::default();
    event_loop(
        &listener,
        opts,
        &mut |batch| {
            let responses: Vec<Json> =
                batch.iter().map(|env| router.answer(env)).collect();
            (router.epoch(), responses)
        },
        &mut acc,
        &mut counters,
    )?;
    let per_request = if counters.requests == 0 {
        0.0
    } else {
        router.merge_wall.as_secs_f64() / counters.requests as f64
    };
    let rows = acc
        .into_iter()
        .map(|(epoch, a)| {
            let mut r = a.into_row(&opts.database, epoch, 1);
            r.shards = shards;
            r.sessions = counters.sessions;
            r.merge_overhead_s = per_request;
            r
        })
        .collect();
    Ok(RouterSummary {
        rows,
        requests: counters.requests,
        errors: counters.errors,
        sessions: counters.sessions,
        session_failures: counters.session_failures,
        merge_wall: router.merge_wall,
        final_epoch: router.epoch(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::fixtures::university_db;
    use crate::delta::{DeltaBatch, DeltaOp, MaintainConfig};
    use crate::serve::engine::ServeEngine;
    use crate::serve::server::serve_listener;
    use crate::serve::shard::ShardConfig;
    use std::io::{BufRead, BufReader, Read, Write};

    fn spawn_shard(
        index: usize,
        of: usize,
        pre: Option<DeltaBatch>,
    ) -> (String, std::thread::JoinHandle<()>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let handle = std::thread::spawn(move || {
            let mut engine =
                ServeEngine::build(university_db(), MaintainConfig::default())
                    .unwrap();
            if let Some(b) = pre {
                engine.apply_publish(&b).unwrap();
            }
            let opts = ServeOptions {
                database: "uw".into(),
                shard: Some(ShardConfig { index, of }),
                ..Default::default()
            };
            serve_listener(engine, listener, &opts).unwrap();
        });
        (addr, handle)
    }

    fn shut_down(addr: &str) {
        let mut s = TcpStream::connect(addr).unwrap();
        writeln!(s, "{}", ServeRequest::Shutdown { id: 0 }.to_json().dump())
            .unwrap();
        let mut line = String::new();
        BufReader::new(&s).read_line(&mut line).unwrap();
    }

    #[test]
    fn routed_responses_are_byte_identical_to_single_process() {
        let (a0, h0) = spawn_shard(0, 2, None);
        let (a1, h1) = spawn_shard(1, 2, None);
        let addrs = vec![a0.clone(), a1.clone()];

        let reqs =
            crate::serve::protocol::enumerate_requests(&university_db(), 3, 8)
                .unwrap();
        let mut input: String =
            reqs.iter().map(|r| r.to_json().dump() + "\n").collect();
        input.push_str(&ServeRequest::Shutdown { id: 99 }.to_json().dump());
        input.push('\n');

        // single-process reference over the identical request stream
        let mut reference = Vec::new();
        let opts = ServeOptions { database: "uw".into(), ..Default::default() };
        crate::serve::server::run_serve(
            ServeEngine::build(university_db(), MaintainConfig::default()).unwrap(),
            std::io::Cursor::new(input.clone()),
            &mut reference,
            &opts,
        )
        .unwrap();

        // the same stream through the 2-shard router
        let rl = TcpListener::bind("127.0.0.1:0").unwrap();
        let raddr = rl.local_addr().unwrap();
        let ropts = ServeOptions { database: "uw".into(), ..Default::default() };
        let router = std::thread::spawn(move || {
            run_router(university_db(), &addrs, rl, &ropts).unwrap()
        });
        let mut client = TcpStream::connect(raddr).unwrap();
        client.write_all(input.as_bytes()).unwrap();
        client.shutdown(std::net::Shutdown::Write).unwrap();
        let mut routed = Vec::new();
        BufReader::new(&client).read_to_end(&mut routed).unwrap();
        let summary = router.join().unwrap();

        assert_eq!(
            String::from_utf8(routed).unwrap(),
            String::from_utf8(reference).unwrap(),
            "routed responses must be byte-identical to single-process serving"
        );
        assert_eq!(summary.requests, 9);
        assert_eq!(summary.errors, 0);
        assert_eq!(summary.final_epoch, 0);
        assert!(summary.rows.iter().all(|r| r.shards == 2));

        shut_down(&a0);
        shut_down(&a1);
        h0.join().unwrap();
        h1.join().unwrap();
    }

    #[test]
    fn unreachable_shard_is_a_typed_route_error() {
        // bind then drop: nothing listens on this address anymore
        let dead = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let mut router = Router::new(university_db(), &[dead]);
        let env = Envelope {
            req: Ok(ServeRequest::Count {
                id: 5,
                vars: vec![RVar::EntityAttr { et: 0, attr: 0 }],
                ctx: vec![0],
            }),
            t0: Instant::now(),
        };
        let resp = router.answer(&env);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
        let msg = resp.get("error").unwrap().as_str().unwrap();
        assert!(msg.starts_with("route error: shard "), "{msg}");
    }

    #[test]
    fn diverged_shards_are_a_typed_route_error() {
        // shard 1 has applied a delta shard 0 never saw: epochs differ,
        // so the pin check must refuse to blend them
        let (a0, h0) = spawn_shard(0, 2, None);
        let drift =
            DeltaBatch::new(vec![DeltaOp::DeleteLink { rel: 0, from: 0, to: 0 }]);
        let (a1, h1) = spawn_shard(1, 2, Some(drift));
        let mut router = Router::new(university_db(), &[a0.clone(), a1.clone()]);
        let env = Envelope {
            req: Ok(ServeRequest::Count {
                id: 1,
                vars: vec![RVar::EntityAttr { et: 0, attr: 0 }],
                ctx: vec![0],
            }),
            t0: Instant::now(),
        };
        let resp = router.answer(&env);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
        let msg = resp.get("error").unwrap().as_str().unwrap();
        assert!(msg.contains("diverged"), "{msg}");
        shut_down(&a0);
        shut_down(&a1);
        h0.join().unwrap();
        h1.join().unwrap();
    }
}
