//! The `relcount serve` front-end: line-delimited JSON requests in,
//! responses out, deltas applied concurrently.
//!
//! Three threads cooperate per session (the channel pattern of
//! [`crate::runtime::batcher::ScoreService`]):
//!
//! - the **pump** reads request lines and feeds a channel (stamping
//!   each request's arrival time).  It is detached, not joined: a
//!   session that ends early (shutdown op, write error) must not wait
//!   on a pump parked in a blocking read — the pump exits on its own
//!   at input EOF or on the first send to the dropped channel;
//! - the **dispatch loop** (the calling thread) drains whatever is
//!   queued — up to [`ServeOptions::batch_max`] — into one micro-batch,
//!   loads the current [`Generation`] **once per batch**, fans the
//!   batch out over the reader pool ([`pool::run_shards`], families
//!   routed by cache-key hash), and writes responses in request order;
//! - the **delta writer** owns the [`ServeEngine`] and streams batches
//!   through [`ServeEngine::apply_publish`], fully concurrent with the
//!   readers — a publish failure is recorded and the stream continues
//!   from the last good generation.
//!
//! Every request in a micro-batch is answered from the same generation
//! (one `load` per batch), so a batch never straddles a publish — the
//! protocol stamps the epoch on each response and the equivalence test
//! holds every answer to *exactly* its stamped generation's counts.
//! Latency, throughput and queue depth are accumulated **per epoch**
//! ([`ServeRow`]) so a regression in publish behavior shows up in the
//! metrics, not just in wall clock.

use std::collections::BTreeMap;
use std::io::{BufRead, Write};
use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::coordinator::{pool, resolve_workers};
use crate::datagen::churn::churn_batch;
use crate::delta::DeltaBatch;
use crate::error::{Error, Result};
use crate::metrics::report::ServeRow;
use crate::serve::engine::{shard_for_family, ServeEngine};
use crate::serve::protocol::{
    count_response, error_response, score_response, shutdown_response, stats_response,
    ServeRequest,
};
use crate::serve::snapshot::{Generation, SnapshotStore};
use crate::util::json::Json;

/// Where the concurrent delta stream comes from.
#[derive(Clone, Debug)]
pub enum DeltaFeed {
    /// Static serving: generation 0 answers everything.
    None,
    /// Pre-parsed batches (one JSON batch per line of `--deltas FILE`).
    Batches(Vec<DeltaBatch>),
    /// Seeded churn generated against the writer's live state right
    /// before each publish (`--churn FRAC --churn-steps K`) — the same
    /// generator as `exp churn`, so the final digest is deterministic
    /// for a given (db, frac, steps, seed) regardless of read traffic.
    Churn { frac: f64, steps: usize, seed: u64 },
}

/// Session configuration.
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Label stamped on the metrics rows.
    pub database: String,
    /// Reader pool width (0 = all cores).
    pub workers: usize,
    /// Micro-batch cap per dispatch.
    pub batch_max: usize,
    pub feed: DeltaFeed,
    /// Pause between publishes, letting readers overlap generations
    /// (zero = apply as fast as possible).
    pub delta_pause: Duration,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            database: String::new(),
            workers: 1,
            batch_max: 64,
            feed: DeltaFeed::None,
            delta_pause: Duration::ZERO,
        }
    }
}

/// Outcome of one serve run.
#[derive(Clone, Debug)]
pub struct ServeSummary {
    /// Per-generation latency/throughput/queue-depth rows.
    pub rows: Vec<ServeRow>,
    pub requests: u64,
    pub errors: u64,
    /// Generations published (successful `apply_publish` calls).
    pub publishes: u64,
    /// `(batch index, error)` for batches that failed to publish — the
    /// previous generation kept serving through each.
    pub publish_failures: Vec<(usize, String)>,
    pub final_epoch: u64,
    /// Writer-state digest after the delta stream quiesced (equals the
    /// last published generation's digest).
    pub final_digest: u64,
}

/// Per-epoch metric accumulator.
#[derive(Default)]
struct GenAccum {
    requests: u64,
    count_requests: u64,
    score_requests: u64,
    errors: u64,
    batches: u64,
    max_queue_depth: u64,
    lat_sum: Duration,
    lat_max: Duration,
    first: Option<Instant>,
    last: Option<Instant>,
}

impl GenAccum {
    fn into_row(self, database: &str, epoch: u64, workers: usize) -> ServeRow {
        let elapsed = match (self.first, self.last) {
            (Some(a), Some(b)) => b.duration_since(a),
            _ => Duration::ZERO,
        };
        ServeRow {
            database: database.to_string(),
            epoch,
            requests: self.requests,
            count_requests: self.count_requests,
            score_requests: self.score_requests,
            errors: self.errors,
            batches: self.batches,
            max_queue_depth: self.max_queue_depth,
            mean_latency: if self.requests == 0 {
                Duration::ZERO
            } else {
                self.lat_sum / self.requests as u32
            },
            max_latency: self.lat_max,
            throughput_rps: if elapsed.is_zero() {
                // single-instant generation: latency is the only clock
                if self.lat_sum.is_zero() {
                    0.0
                } else {
                    self.requests as f64 / self.lat_sum.as_secs_f64()
                }
            } else {
                self.requests as f64 / elapsed.as_secs_f64()
            },
            workers,
        }
    }
}

/// One in-flight request (parse errors ride along so responses keep
/// input order).
struct Envelope {
    req: Result<ServeRequest>,
    t0: Instant,
}

/// Run a full serve session: `input` request lines answered onto `out`
/// while the delta feed publishes generations concurrently.  Returns
/// once the input is exhausted **and** the delta stream has quiesced.
pub fn run_serve<R, W>(
    engine: ServeEngine,
    input: R,
    mut out: W,
    opts: &ServeOptions,
) -> Result<ServeSummary>
where
    R: BufRead + Send + 'static,
    W: Write,
{
    let store = engine.store();
    let feed = opts.feed.clone();
    let pause = opts.delta_pause;
    let mut acc: BTreeMap<u64, GenAccum> = BTreeMap::new();
    let mut requests = 0u64;
    let mut errors = 0u64;

    let (engine, publishes, publish_failures, session) =
        std::thread::scope(|scope| {
            let delta = scope.spawn(move || drive_deltas(engine, feed, pause));
            let session = session_loop(
                &store,
                input,
                &mut out,
                opts,
                &mut acc,
                &mut requests,
                &mut errors,
            );
            let (engine, publishes, failures) =
                delta.join().expect("delta writer panicked");
            (engine, publishes, failures, session)
        });
    session?;

    let rows = acc
        .into_iter()
        .map(|(epoch, a)| a.into_row(&opts.database, epoch, resolve_workers(opts.workers)))
        .collect();
    Ok(ServeSummary {
        rows,
        requests,
        errors,
        publishes,
        publish_failures,
        final_epoch: engine.epoch(),
        final_digest: engine.digest(),
    })
}

/// The delta writer: apply-and-publish every batch of the feed,
/// surviving failures (the stream continues from the last good
/// generation).  Returns the engine for the final digest.  When a data
/// directory is attached, the quiesced state is snapshotted before
/// returning — the graceful-shutdown snapshot — so a clean restart
/// loads the final generation without replaying the whole WAL.
fn drive_deltas(
    mut engine: ServeEngine,
    feed: DeltaFeed,
    pause: Duration,
) -> (ServeEngine, u64, Vec<(usize, String)>) {
    let mut publishes = 0u64;
    let mut failures = Vec::new();
    let mut publish = |engine: &mut ServeEngine, i: usize, batch: &DeltaBatch| {
        match engine.apply_publish(batch) {
            Ok(_) => publishes += 1,
            Err(e) => failures.push((i, e.to_string())),
        }
        if !pause.is_zero() {
            std::thread::sleep(pause);
        }
    };
    match feed {
        DeltaFeed::None => {}
        DeltaFeed::Batches(batches) => {
            for (i, b) in batches.iter().enumerate() {
                publish(&mut engine, i, b);
            }
        }
        DeltaFeed::Churn { frac, steps, seed } => {
            for i in 0..steps {
                // generated against the *current* writer state, so every
                // op is valid and the sequence is seed-deterministic
                let b = churn_batch(engine.db(), frac, seed ^ (i as u64 + 1));
                publish(&mut engine, i, &b);
            }
        }
    }
    drop(publish);
    if let Err(e) = engine.persist_snapshot() {
        // the WAL still holds every batch; recovery replays from the
        // previous snapshot, so this is reported, not fatal
        failures.push((usize::MAX, format!("shutdown snapshot: {e}")));
    }
    (engine, publishes, failures)
}

/// The dispatch loop of one client session (see the module docs).
///
/// `requests`/`errors` are accumulated through the caller's counters —
/// not returned — so a session that dies mid-stream (write error,
/// client disconnect) still contributes everything it served before
/// failing to the [`ServeSummary`].
fn session_loop<R, W>(
    store: &SnapshotStore,
    input: R,
    out: &mut W,
    opts: &ServeOptions,
    acc: &mut BTreeMap<u64, GenAccum>,
    requests: &mut u64,
    errors: &mut u64,
) -> Result<bool>
where
    R: BufRead + Send + 'static,
    W: Write,
{
    let workers = resolve_workers(opts.workers);
    let batch_max = opts.batch_max.max(1);
    let mut shutdown = false;

    // Detached on purpose: a pump parked in a blocking read must not be
    // joined by a session that ends early (shutdown op, write error) —
    // it exits at input EOF or on the first send to the dropped channel.
    let (tx, rx) = mpsc::channel::<Envelope>();
    std::thread::spawn(move || {
        for line in input.lines() {
            let env = match line {
                Ok(l) if l.trim().is_empty() => continue,
                Ok(l) => Envelope { req: ServeRequest::parse(&l), t0: Instant::now() },
                Err(e) => Envelope { req: Err(e.into()), t0: Instant::now() },
            };
            if tx.send(env).is_err() {
                return; // dispatch loop gone
            }
        }
    });

    let mut pending: Vec<Envelope> = Vec::new();
    loop {
        match rx.recv() {
            Ok(env) => pending.push(env),
            Err(_) => break, // pump done and channel drained
        }
        while pending.len() < batch_max {
            match rx.try_recv() {
                Ok(env) => pending.push(env),
                Err(_) => break,
            }
        }
        let depth = pending.len() as u64;
        // one generation per micro-batch: the batch never straddles
        // a publish, and each response is stamped with its epoch
        let gen = store.load();
        // the serving window opens when compute starts, not when the
        // first response is written — else single-batch generations
        // would report the write loop's elapsed time as the window
        // and wildly inflate throughput_rps
        let batch_start = Instant::now();
        let responses = dispatch(&gen, &pending, workers);

        let a = acc.entry(gen.epoch).or_default();
        a.batches += 1;
        a.max_queue_depth = a.max_queue_depth.max(depth);
        a.first.get_or_insert(batch_start);
        for (env, resp) in pending.drain(..).zip(responses) {
            let ok = matches!(resp.get("ok"), Some(Json::Bool(true)));
            *requests += 1;
            a.requests += 1;
            match &env.req {
                Ok(ServeRequest::Count { .. }) => a.count_requests += 1,
                Ok(ServeRequest::Score { .. }) => a.score_requests += 1,
                Ok(ServeRequest::Shutdown { .. }) => shutdown = true,
                _ => {}
            }
            if !ok {
                *errors += 1;
                a.errors += 1;
            }
            let lat = env.t0.elapsed();
            a.lat_sum += lat;
            a.lat_max = a.lat_max.max(lat);
            writeln!(out, "{}", resp.dump())?;
        }
        a.last = Some(Instant::now());
        out.flush()?;
        if shutdown {
            break; // stop reading; the pump exits on its dead channel
        }
    }
    Ok(shutdown)
}

/// TCP mode: serve sessions from `listener` sequentially (one client at
/// a time; every session shares the store, so later clients see the
/// generations earlier ones advanced past).  Runs until a client sends
/// `{"op": "shutdown"}`, then quiesces the delta stream and returns the
/// summary.
pub fn serve_listener(
    engine: ServeEngine,
    listener: std::net::TcpListener,
    opts: &ServeOptions,
) -> Result<ServeSummary> {
    let store = engine.store();
    let feed = opts.feed.clone();
    let pause = opts.delta_pause;
    let mut acc: BTreeMap<u64, GenAccum> = BTreeMap::new();
    let mut requests = 0u64;
    let mut errors = 0u64;

    let (engine, publishes, publish_failures, session) =
        std::thread::scope(|scope| {
            let delta = scope.spawn(move || drive_deltas(engine, feed, pause));
            let session = (|| -> Result<()> {
                loop {
                    let (stream, peer) = listener.accept()?;
                    // one client's I/O failure (disconnect mid-response,
                    // broken clone) ends that session, not the server —
                    // and the counters live outside the session, so
                    // whatever it served before failing still counts
                    let ended = (|| -> Result<bool> {
                        let reader = std::io::BufReader::new(stream.try_clone()?);
                        let mut writer = stream;
                        session_loop(
                            &store,
                            reader,
                            &mut writer,
                            opts,
                            &mut acc,
                            &mut requests,
                            &mut errors,
                        )
                    })();
                    match ended {
                        Ok(shutdown) => {
                            if shutdown {
                                return Ok(());
                            }
                        }
                        Err(e) => {
                            eprintln!("serve: session {peer} failed: {e}; still accepting");
                        }
                    }
                }
            })();
            let (engine, publishes, failures) =
                delta.join().expect("delta writer panicked");
            (engine, publishes, failures, session)
        });
    session?;

    let rows = acc
        .into_iter()
        .map(|(epoch, a)| a.into_row(&opts.database, epoch, resolve_workers(opts.workers)))
        .collect();
    Ok(ServeSummary {
        rows,
        requests,
        errors,
        publishes,
        publish_failures,
        final_epoch: engine.epoch(),
        final_digest: engine.digest(),
    })
}

/// Answer one micro-batch from one generation: requests fan out over
/// the reader pool (families routed by cache-key hash, stats and parse
/// errors answered on worker 0), responses in request order.
fn dispatch(gen: &Generation, batch: &[Envelope], workers: usize) -> Vec<Json> {
    let mut assignment: Vec<Vec<usize>> = vec![Vec::new(); workers.max(1)];
    for (i, env) in batch.iter().enumerate() {
        let w = match &env.req {
            Ok(ServeRequest::Count { vars, ctx, .. })
            | Ok(ServeRequest::Score { vars, ctx, .. }) => {
                shard_for_family(vars, ctx, workers)
            }
            _ => 0,
        };
        assignment[w].push(i);
    }
    let run = pool::run_shards(batch, &assignment, |_, env| Ok(answer(gen, env)));
    run.results
        .into_iter()
        .map(|r| r.expect("answer() is infallible"))
        .collect()
}

/// Serve one request from one generation; failures become in-protocol
/// error responses (the session keeps going).
fn answer(gen: &Generation, env: &Envelope) -> Json {
    match &env.req {
        Err(e) => error_response(0, e),
        Ok(ServeRequest::Count { id, vars, ctx }) => {
            match gen.ct_for_family(vars, ctx) {
                Ok(ct) => count_response(*id, gen.epoch, &ct),
                Err(e) => error_response(*id, &e),
            }
        }
        Ok(ServeRequest::Score { id, vars, ctx, child, n_prime }) => {
            match gen.score_family(vars, ctx, child, *n_prime) {
                Ok(s) => score_response(*id, gen.epoch, s),
                Err(e) => error_response(*id, &e),
            }
        }
        Ok(ServeRequest::Stats { id }) => {
            stats_response(*id, gen.epoch, gen.resident_bytes(), gen.digest())
        }
        Ok(ServeRequest::Shutdown { id }) => shutdown_response(*id, gen.epoch),
    }
}

/// Parse a line-delimited delta stream (one JSON batch per non-empty
/// line) — the `--deltas` wire format of `relcount serve`.  A file
/// holding a single JSON array still parses (one batch).
pub fn parse_delta_stream(text: &str) -> Result<Vec<DeltaBatch>> {
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        out.push(DeltaBatch::parse_json(line).map_err(|e| {
            Error::Data(format!("delta stream line {}: {e}", i + 1))
        })?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::fixtures::university_db;
    use crate::delta::{DeltaOp, MaintainConfig};

    fn lines(reqs: &[ServeRequest]) -> String {
        reqs.iter().map(|r| r.to_json().dump() + "\n").collect()
    }

    fn engine() -> ServeEngine {
        ServeEngine::build(university_db(), MaintainConfig::default()).unwrap()
    }

    fn requests() -> Vec<ServeRequest> {
        crate::serve::protocol::enumerate_requests(&university_db(), 3, 20).unwrap()
    }

    #[test]
    fn static_serving_is_bit_identical_across_worker_counts() {
        let input = lines(&requests());
        let mut outputs = Vec::new();
        for workers in [1usize, 4] {
            let mut out = Vec::new();
            let opts = ServeOptions {
                database: "uw".into(),
                workers,
                ..Default::default()
            };
            let summary = run_serve(
                engine(),
                std::io::Cursor::new(input.clone()),
                &mut out,
                &opts,
            )
            .unwrap();
            assert_eq!(summary.requests, 20);
            assert_eq!(summary.errors, 0);
            assert_eq!(summary.final_epoch, 0);
            outputs.push(out);
        }
        assert_eq!(outputs[0], outputs[1], "responses must not depend on workers");
    }

    #[test]
    fn serving_continues_through_publish_failures() {
        let good = DeltaBatch::new(vec![DeltaOp::DeleteLink { rel: 0, from: 0, to: 0 }]);
        let bad = DeltaBatch::new(vec![DeltaOp::DeleteLink { rel: 0, from: 0, to: 0 }]);
        // `bad` deletes the same pair again -> fails mid-stream
        let after = DeltaBatch::new(vec![DeltaOp::InsertLink {
            rel: 0,
            from: 0,
            to: 0,
            values: vec![3, 2],
        }]);
        let input = lines(&requests());
        let mut out = Vec::new();
        let opts = ServeOptions {
            database: "uw".into(),
            workers: 2,
            feed: DeltaFeed::Batches(vec![good, bad, after]),
            ..Default::default()
        };
        let summary =
            run_serve(engine(), std::io::Cursor::new(input), &mut out, &opts).unwrap();
        assert_eq!(summary.publishes, 2);
        assert_eq!(summary.publish_failures.len(), 1);
        assert_eq!(summary.publish_failures[0].0, 1);
        assert_eq!(summary.final_epoch, 2);
        assert_eq!(summary.errors, 0, "reads never fail through a bad publish");
        // delete + exact reinsert: the final state equals the initial one
        assert_eq!(summary.final_digest, engine().digest());
    }

    #[test]
    fn churn_feed_final_digest_matches_direct_application() {
        let opts = ServeOptions {
            database: "uw".into(),
            workers: 2,
            feed: DeltaFeed::Churn { frac: 0.2, steps: 2, seed: 99 },
            ..Default::default()
        };
        let input = lines(&requests());
        let mut out = Vec::new();
        let summary =
            run_serve(engine(), std::io::Cursor::new(input), &mut out, &opts).unwrap();
        assert_eq!(summary.final_epoch, 2);

        // the same churn applied without any read traffic lands on the
        // same digest: reads are isolated from writes
        let mut direct = engine();
        for i in 0..2u64 {
            let b = churn_batch(direct.db(), 0.2, 99 ^ (i + 1));
            direct.apply_publish(&b).unwrap();
        }
        assert_eq!(summary.final_digest, direct.digest());
        // per-generation rows cover only epochs that served requests
        assert!(!summary.rows.is_empty());
        let served: u64 = summary.rows.iter().map(|r| r.requests).sum();
        assert_eq!(served, summary.requests);
    }

    #[test]
    fn malformed_lines_answer_in_order_and_session_survives() {
        let input = format!(
            "{}\nnot json at all\n{}\n",
            ServeRequest::Stats { id: 7 }.to_json().dump(),
            ServeRequest::Stats { id: 8 }.to_json().dump(),
        );
        let mut out = Vec::new();
        let opts = ServeOptions { database: "uw".into(), ..Default::default() };
        let summary =
            run_serve(engine(), std::io::Cursor::new(input), &mut out, &opts).unwrap();
        assert_eq!(summary.requests, 3);
        assert_eq!(summary.errors, 1);
        let text = String::from_utf8(out).unwrap();
        let ids: Vec<f64> = text
            .lines()
            .map(|l| Json::parse(l).unwrap().get("id").unwrap().as_f64().unwrap())
            .collect();
        assert_eq!(ids, vec![7.0, 0.0, 8.0]);
    }

    #[test]
    fn tcp_sessions_serve_until_shutdown() {
        use std::io::{BufRead, BufReader, Write};
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = std::thread::spawn(move || {
            let mut answers = Vec::new();
            // session 1: one stats request, then EOF
            let mut s1 = std::net::TcpStream::connect(addr).unwrap();
            writeln!(s1, "{}", ServeRequest::Stats { id: 1 }.to_json().dump()).unwrap();
            s1.shutdown(std::net::Shutdown::Write).unwrap();
            let mut line = String::new();
            BufReader::new(&s1).read_line(&mut line).unwrap();
            answers.push(line);
            // session 2: a count, then shutdown
            let mut s2 = std::net::TcpStream::connect(addr).unwrap();
            let req = crate::serve::protocol::enumerate_requests(&university_db(), 3, 1)
                .unwrap()
                .remove(0);
            writeln!(s2, "{}", req.to_json().dump()).unwrap();
            writeln!(s2, "{}", ServeRequest::Shutdown { id: 9 }.to_json().dump())
                .unwrap();
            s2.shutdown(std::net::Shutdown::Write).unwrap();
            let mut r2 = BufReader::new(&s2);
            for _ in 0..2 {
                let mut line = String::new();
                r2.read_line(&mut line).unwrap();
                answers.push(line);
            }
            answers
        });
        let opts = ServeOptions { database: "uw".into(), ..Default::default() };
        let summary = serve_listener(engine(), listener, &opts).unwrap();
        let answers = client.join().unwrap();
        assert_eq!(summary.requests, 3);
        assert_eq!(summary.errors, 0);
        for line in &answers {
            let j = Json::parse(line).unwrap();
            assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "{line}");
        }
    }

    /// Accepts `limit` full response lines, then fails — a
    /// deterministic stand-in for a client that disconnects
    /// mid-response.
    struct FailingWriter {
        lines: usize,
        limit: usize,
    }

    impl Write for FailingWriter {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            if self.lines >= self.limit {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::BrokenPipe,
                    "client gone",
                ));
            }
            self.lines += buf.iter().filter(|&&b| b == b'\n').count();
            Ok(buf.len())
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn failed_session_still_contributes_its_counters() {
        // PR 5 review finding: a session that died mid-stream lost its
        // (requests, errors) from the summary.  The counters now live
        // with the caller, so everything answered before the failure
        // survives the error return.
        let e = engine();
        let store = e.store();
        let input = format!(
            "{}\nnot json\n{}\n{}\n",
            ServeRequest::Stats { id: 1 }.to_json().dump(),
            ServeRequest::Stats { id: 2 }.to_json().dump(),
            ServeRequest::Stats { id: 3 }.to_json().dump(),
        );
        let opts = ServeOptions {
            database: "uw".into(),
            batch_max: 1, // one response per dispatch: the failure point is exact
            ..Default::default()
        };
        let mut acc = BTreeMap::new();
        let mut requests = 0u64;
        let mut errors = 0u64;
        let mut out = FailingWriter { lines: 0, limit: 2 };
        let r = session_loop(
            &store,
            std::io::Cursor::new(input),
            &mut out,
            &opts,
            &mut acc,
            &mut requests,
            &mut errors,
        );
        assert!(r.is_err(), "third response write must fail the session");
        // everything answered before the broken pipe is retained: the
        // ok stats, the parse error, and the response that hit the pipe
        assert_eq!(requests, 3);
        assert_eq!(errors, 1);
    }

    #[test]
    fn delta_stream_parses_line_delimited_batches() {
        let b1 = DeltaBatch::new(vec![DeltaOp::InsertEntity { et: 0, values: vec![1] }]);
        let b2 = DeltaBatch::new(vec![DeltaOp::DeleteLink { rel: 0, from: 0, to: 0 }]);
        let text = format!("{}\n\n{}\n", b1.to_json().dump(), b2.to_json().dump());
        let parsed = parse_delta_stream(&text).unwrap();
        assert_eq!(parsed, vec![b1, b2]);
        assert!(parse_delta_stream("nope\n").is_err());
    }
}
