//! The `relcount serve` front-end: line-delimited JSON requests in,
//! responses out, deltas applied concurrently.
//!
//! Three threads cooperate per session (the channel pattern of
//! [`crate::runtime::batcher::ScoreService`]):
//!
//! - the **pump** reads request lines and feeds a channel (stamping
//!   each request's arrival time).  It is detached, not joined: a
//!   session that ends early (shutdown op, write error) must not wait
//!   on a pump parked in a blocking read — the pump exits on its own
//!   at input EOF or on the first send to the dropped channel;
//! - the **dispatch loop** (the calling thread) drains whatever is
//!   queued — up to [`ServeOptions::batch_max`] — into one micro-batch,
//!   loads the current [`Generation`] **once per batch**, fans the
//!   batch out over the reader pool ([`pool::run_shards`], families
//!   routed by cache-key hash), and writes responses in request order;
//! - the **delta writer** owns the [`ServeEngine`] and streams batches
//!   through [`ServeEngine::apply_publish`], fully concurrent with the
//!   readers — a publish failure is recorded and the stream continues
//!   from the last good generation.
//!
//! Every request in a micro-batch is answered from the same generation
//! (one `load` per batch), so a batch never straddles a publish — the
//! protocol stamps the epoch on each response and the equivalence test
//! holds every answer to *exactly* its stamped generation's counts.
//! Latency, throughput and queue depth are accumulated **per epoch**
//! ([`ServeRow`]) so a regression in publish behavior shows up in the
//! metrics, not just in wall clock.
//!
//! TCP mode ([`serve_listener`]) runs a **readiness-polled, non-blocking
//! event loop** ([`event_loop`]) instead of the stdin pump: every client
//! socket is non-blocking with per-session read/write buffers, complete
//! lines are micro-batched through the same dispatch path, and partial
//! writes park in the session's buffer until the socket drains — many
//! concurrent sessions on one thread, no thread-per-connection.  One
//! session's failure (oversized line, mid-request disconnect, broken
//! pipe) is recorded in [`ServeSummary::session_failures`] while every
//! other session keeps serving.

use std::collections::BTreeMap;
use std::io::{BufRead, Read, Write};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coordinator::{pool, resolve_workers};
use crate::datagen::churn::churn_batch;
use crate::delta::DeltaBatch;
use crate::error::{Error, Result};
use crate::metrics::report::ServeRow;
use crate::serve::engine::{shard_for_family, ServeEngine};
use crate::serve::protocol::{
    count_response, error_response, score_response, shutdown_response,
    stats_response_ext, ServeRequest,
};
use crate::serve::replicate::{ReplHandle, ReplLog, ReplRecord};
use crate::serve::shard::ShardConfig;
use crate::serve::snapshot::{Generation, SnapshotStore};
use crate::util::json::Json;

/// Per-session request-line cap of the TCP event loop: a line that grows
/// past this without a newline fails its session typed instead of
/// buffering without bound.
pub const MAX_LINE: usize = 1 << 20;

/// Where the concurrent delta stream comes from.
#[derive(Clone, Debug)]
pub enum DeltaFeed {
    /// Static serving: generation 0 answers everything.
    None,
    /// Pre-parsed batches (one JSON batch per line of `--deltas FILE`).
    Batches(Vec<DeltaBatch>),
    /// Seeded churn generated against the writer's live state right
    /// before each publish (`--churn FRAC --churn-steps K`) — the same
    /// generator as `exp churn`, so the final digest is deterministic
    /// for a given (db, frac, steps, seed) regardless of read traffic.
    Churn { frac: f64, steps: usize, seed: u64 },
    /// Follower replication (`--follow ADDR`): consume the leader's
    /// epoch-stamped `DeltaBatch` stream and independently apply-publish
    /// each batch, hard-checking the published digest against the
    /// leader's per-record digest (divergence stops consumption and is
    /// reported in [`ServeSummary::publish_failures`]).
    Follow { addr: String },
}

/// Session configuration.
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Label stamped on the metrics rows.
    pub database: String,
    /// Reader pool width (0 = all cores).
    pub workers: usize,
    /// Micro-batch cap per dispatch.
    pub batch_max: usize,
    pub feed: DeltaFeed,
    /// Pause between publishes, letting readers overlap generations
    /// (zero = apply as fast as possible).
    pub delta_pause: Duration,
    /// Set on `relcount shard` processes: answer `pcount`/`pmarginal`
    /// with this slice's partial tables (plain servers reject them).
    pub shard: Option<ShardConfig>,
    /// Follower lag/health gauges, surfaced through the stats response
    /// when present (set alongside `DeltaFeed::Follow`).
    pub repl: Option<Arc<ReplHandle>>,
    /// Leader side of replication: every successful publish is appended
    /// here (and the log closed at quiesce) for the acceptor to stream
    /// to followers.
    pub publish_log: Option<Arc<ReplLog>>,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            database: String::new(),
            workers: 1,
            batch_max: 64,
            feed: DeltaFeed::None,
            delta_pause: Duration::ZERO,
            shard: None,
            repl: None,
            publish_log: None,
        }
    }
}

/// Outcome of one serve run.
#[derive(Clone, Debug)]
pub struct ServeSummary {
    /// Per-generation latency/throughput/queue-depth rows.
    pub rows: Vec<ServeRow>,
    pub requests: u64,
    pub errors: u64,
    /// Generations published (successful `apply_publish` calls).
    pub publishes: u64,
    /// `(batch index, error)` for batches that failed to publish — the
    /// previous generation kept serving through each.
    pub publish_failures: Vec<(usize, String)>,
    pub final_epoch: u64,
    /// Writer-state digest after the delta stream quiesced (equals the
    /// last published generation's digest).
    pub final_digest: u64,
    /// Sessions accepted (1 for stdin/file serving).
    pub sessions: u64,
    /// `(session id, error)` for sessions that died mid-stream
    /// (oversized line, disconnect, write failure) — everything they
    /// served before failing is still counted above.
    pub session_failures: Vec<(u64, String)>,
}

/// Per-epoch metric accumulator.
#[derive(Default)]
pub(crate) struct GenAccum {
    requests: u64,
    count_requests: u64,
    score_requests: u64,
    errors: u64,
    batches: u64,
    max_queue_depth: u64,
    lat_sum: Duration,
    lat_max: Duration,
    /// Capped reservoir of per-request latencies for the p50/p99
    /// columns (first come, first kept — enough for the bench rows
    /// without unbounded memory on long runs).
    lat_samples: Vec<Duration>,
    first: Option<Instant>,
    last: Option<Instant>,
}

/// Cap on [`GenAccum::lat_samples`].
const LAT_SAMPLE_CAP: usize = 65_536;

/// Nearest-rank percentile over an unsorted sample set (sorts a copy).
fn percentile_s(samples: &[Duration], p: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut s: Vec<Duration> = samples.to_vec();
    s.sort_unstable();
    let idx = ((s.len() - 1) as f64 * p).round() as usize;
    s[idx.min(s.len() - 1)].as_secs_f64()
}

impl GenAccum {
    fn note_latency(&mut self, lat: Duration) {
        self.lat_sum += lat;
        self.lat_max = self.lat_max.max(lat);
        if self.lat_samples.len() < LAT_SAMPLE_CAP {
            self.lat_samples.push(lat);
        }
    }

    pub(crate) fn into_row(self, database: &str, epoch: u64, workers: usize) -> ServeRow {
        let elapsed = match (self.first, self.last) {
            (Some(a), Some(b)) => b.duration_since(a),
            _ => Duration::ZERO,
        };
        let p50_latency_s = percentile_s(&self.lat_samples, 0.50);
        let p99_latency_s = percentile_s(&self.lat_samples, 0.99);
        ServeRow {
            database: database.to_string(),
            epoch,
            requests: self.requests,
            count_requests: self.count_requests,
            score_requests: self.score_requests,
            errors: self.errors,
            batches: self.batches,
            max_queue_depth: self.max_queue_depth,
            mean_latency: if self.requests == 0 {
                Duration::ZERO
            } else {
                self.lat_sum / self.requests as u32
            },
            max_latency: self.lat_max,
            throughput_rps: if elapsed.is_zero() {
                // single-instant generation: latency is the only clock
                if self.lat_sum.is_zero() {
                    0.0
                } else {
                    self.requests as f64 / self.lat_sum.as_secs_f64()
                }
            } else {
                self.requests as f64 / elapsed.as_secs_f64()
            },
            workers,
            p50_latency_s,
            p99_latency_s,
            // single-process defaults; the sharded bench scenario and
            // serve_listener overwrite these on their rows
            shards: 0,
            sessions: 0,
            merge_overhead_s: 0.0,
            follower_lag: 0.0,
        }
    }
}

/// One in-flight request (parse errors ride along so responses keep
/// input order).
pub(crate) struct Envelope {
    pub(crate) req: Result<ServeRequest>,
    pub(crate) t0: Instant,
}

/// Run a full serve session: `input` request lines answered onto `out`
/// while the delta feed publishes generations concurrently.  Returns
/// once the input is exhausted **and** the delta stream has quiesced.
pub fn run_serve<R, W>(
    engine: ServeEngine,
    input: R,
    mut out: W,
    opts: &ServeOptions,
) -> Result<ServeSummary>
where
    R: BufRead + Send + 'static,
    W: Write,
{
    let store = engine.store();
    let feed = opts.feed.clone();
    let pause = opts.delta_pause;
    let log = opts.publish_log.clone();
    let repl = opts.repl.clone();
    let mut acc: BTreeMap<u64, GenAccum> = BTreeMap::new();
    let mut requests = 0u64;
    let mut errors = 0u64;

    let (engine, publishes, publish_failures, session) =
        std::thread::scope(|scope| {
            let delta =
                scope.spawn(move || drive_deltas(engine, feed, pause, log, repl));
            let session = session_loop(
                &store,
                input,
                &mut out,
                opts,
                &mut acc,
                &mut requests,
                &mut errors,
            );
            let (engine, publishes, failures) =
                delta.join().expect("delta writer panicked");
            (engine, publishes, failures, session)
        });
    session?;

    let rows = acc
        .into_iter()
        .map(|(epoch, a)| {
            let mut r =
                a.into_row(&opts.database, epoch, resolve_workers(opts.workers));
            r.sessions = 1;
            r
        })
        .collect();
    Ok(ServeSummary {
        rows,
        requests,
        errors,
        publishes,
        publish_failures,
        final_epoch: engine.epoch(),
        final_digest: engine.digest(),
        sessions: 1,
        session_failures: Vec::new(),
    })
}

/// The delta writer: apply-and-publish every batch of the feed,
/// surviving failures (the stream continues from the last good
/// generation).  Returns the engine for the final digest.  When a data
/// directory is attached, the quiesced state is snapshotted before
/// returning — the graceful-shutdown snapshot — so a clean restart
/// loads the final generation without replaying the whole WAL.
fn drive_deltas(
    mut engine: ServeEngine,
    feed: DeltaFeed,
    pause: Duration,
    log: Option<Arc<ReplLog>>,
    repl: Option<Arc<ReplHandle>>,
) -> (ServeEngine, u64, Vec<(usize, String)>) {
    let mut publishes = 0u64;
    let mut failures = Vec::new();
    match feed {
        DeltaFeed::None => {}
        DeltaFeed::Batches(batches) => {
            for (i, b) in batches.iter().enumerate() {
                publish_one(
                    &mut engine,
                    i,
                    b,
                    pause,
                    &mut publishes,
                    &mut failures,
                    log.as_deref(),
                );
            }
        }
        DeltaFeed::Churn { frac, steps, seed } => {
            for i in 0..steps {
                // generated against the *current* writer state, so every
                // op is valid and the sequence is seed-deterministic
                let b = churn_batch(engine.db(), frac, seed ^ (i as u64 + 1));
                publish_one(
                    &mut engine,
                    i,
                    &b,
                    pause,
                    &mut publishes,
                    &mut failures,
                    log.as_deref(),
                );
            }
        }
        DeltaFeed::Follow { addr } => {
            let (p, mut fails) = crate::serve::replicate::follow(
                &addr,
                &mut engine,
                repl.as_deref(),
                pause,
            );
            publishes += p;
            failures.append(&mut fails);
        }
    }
    // quiesced: followers waiting on the log get their eof marker even
    // when the feed published nothing
    if let Some(l) = &log {
        l.close();
    }
    if let Err(e) = engine.persist_snapshot() {
        // the WAL still holds every batch; recovery replays from the
        // previous snapshot, so this is reported, not fatal
        failures.push((usize::MAX, format!("shutdown snapshot: {e}")));
    }
    (engine, publishes, failures)
}

/// Apply-and-publish one batch, recording the outcome; on success the
/// epoch-stamped record is appended to the replication log (if any) so
/// followers replay the exact sequence the leader published.
fn publish_one(
    engine: &mut ServeEngine,
    i: usize,
    batch: &DeltaBatch,
    pause: Duration,
    publishes: &mut u64,
    failures: &mut Vec<(usize, String)>,
    log: Option<&ReplLog>,
) {
    match engine.apply_publish(batch) {
        Ok(_) => {
            *publishes += 1;
            if let Some(l) = log {
                l.append(ReplRecord {
                    epoch: engine.epoch(),
                    digest: engine.digest(),
                    batch: batch.clone(),
                });
            }
        }
        Err(e) => failures.push((i, e.to_string())),
    }
    if !pause.is_zero() {
        std::thread::sleep(pause);
    }
}

/// The dispatch loop of one client session (see the module docs).
///
/// `requests`/`errors` are accumulated through the caller's counters —
/// not returned — so a session that dies mid-stream (write error,
/// client disconnect) still contributes everything it served before
/// failing to the [`ServeSummary`].
fn session_loop<R, W>(
    store: &SnapshotStore,
    input: R,
    out: &mut W,
    opts: &ServeOptions,
    acc: &mut BTreeMap<u64, GenAccum>,
    requests: &mut u64,
    errors: &mut u64,
) -> Result<bool>
where
    R: BufRead + Send + 'static,
    W: Write,
{
    let workers = resolve_workers(opts.workers);
    let batch_max = opts.batch_max.max(1);
    let mut shutdown = false;

    // Detached on purpose: a pump parked in a blocking read must not be
    // joined by a session that ends early (shutdown op, write error) —
    // it exits at input EOF or on the first send to the dropped channel.
    let (tx, rx) = mpsc::channel::<Envelope>();
    std::thread::spawn(move || {
        for line in input.lines() {
            let env = match line {
                Ok(l) if l.trim().is_empty() => continue,
                Ok(l) => Envelope { req: ServeRequest::parse(&l), t0: Instant::now() },
                Err(e) => Envelope { req: Err(e.into()), t0: Instant::now() },
            };
            if tx.send(env).is_err() {
                return; // dispatch loop gone
            }
        }
    });

    let mut pending: Vec<Envelope> = Vec::new();
    loop {
        match rx.recv() {
            Ok(env) => pending.push(env),
            Err(_) => break, // pump done and channel drained
        }
        while pending.len() < batch_max {
            match rx.try_recv() {
                Ok(env) => pending.push(env),
                Err(_) => break,
            }
        }
        let depth = pending.len() as u64;
        // one generation per micro-batch: the batch never straddles
        // a publish, and each response is stamped with its epoch
        let gen = store.load();
        // the serving window opens when compute starts, not when the
        // first response is written — else single-batch generations
        // would report the write loop's elapsed time as the window
        // and wildly inflate throughput_rps
        let batch_start = Instant::now();
        let responses = dispatch(&gen, &pending, workers, opts);

        let a = acc.entry(gen.epoch).or_default();
        a.batches += 1;
        a.max_queue_depth = a.max_queue_depth.max(depth);
        a.first.get_or_insert(batch_start);
        for (env, resp) in pending.drain(..).zip(responses) {
            let ok = matches!(resp.get("ok"), Some(Json::Bool(true)));
            *requests += 1;
            a.requests += 1;
            match &env.req {
                Ok(ServeRequest::Count { .. }) => a.count_requests += 1,
                Ok(ServeRequest::Score { .. }) => a.score_requests += 1,
                Ok(ServeRequest::Shutdown { .. }) => shutdown = true,
                _ => {}
            }
            if !ok {
                *errors += 1;
                a.errors += 1;
            }
            a.note_latency(env.t0.elapsed());
            writeln!(out, "{}", resp.dump())?;
        }
        a.last = Some(Instant::now());
        out.flush()?;
        if shutdown {
            break; // stop reading; the pump exits on its dead channel
        }
    }
    Ok(shutdown)
}

/// Counters an [`event_loop`] run accumulates across all its sessions.
#[derive(Default)]
pub(crate) struct ServeCounters {
    pub requests: u64,
    pub errors: u64,
    pub sessions: u64,
    pub session_failures: Vec<(u64, String)>,
}

/// One client of the event loop: a non-blocking socket with its own
/// read/write buffers, so a slow peer parks bytes here instead of
/// blocking the loop.
struct Session {
    stream: std::net::TcpStream,
    id: u64,
    rbuf: Vec<u8>,
    wbuf: Vec<u8>,
    read_closed: bool,
}

/// Parse one raw request line into an envelope (empty lines skipped, so
/// they cost nothing — matching the stdin pump).
fn push_env(bytes: &[u8], envs: &mut Vec<Envelope>) {
    let t0 = Instant::now();
    let req = match std::str::from_utf8(bytes) {
        Ok(s) if s.trim().is_empty() => return,
        Ok(s) => ServeRequest::parse(s),
        Err(e) => Err(Error::Data(format!("non-utf8 request line: {e}"))),
    };
    envs.push(Envelope { req, t0 });
}

/// Write as much of `buf` as the socket accepts right now; returns the
/// bytes consumed (the rest stays queued for the next readiness pass).
fn write_some(stream: &mut std::net::TcpStream, buf: &[u8]) -> std::io::Result<usize> {
    let mut written = 0;
    while written < buf.len() {
        match stream.write(&buf[written..]) {
            Ok(0) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::WriteZero,
                    "socket accepted zero bytes",
                ))
            }
            Ok(n) => written += n,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    Ok(written)
}

/// The readiness-polled multi-client loop behind [`serve_listener`] and
/// the scale-out router: accept without blocking, drain each session's
/// socket into its line buffer, micro-batch complete lines through
/// `serve_batch` (which returns the serving epoch plus one response per
/// envelope, in order), and flush responses back through per-session
/// write buffers that tolerate partial writes.  Runs until a shutdown
/// response has been issued and every surviving session's write buffer
/// has drained (bounded by a grace period, so a shutdown requester that
/// never reads its acknowledgement cannot wedge the server).
///
/// A failed session — oversized request line, non-utf8 bytes at a line
/// boundary we can't parse past, mid-request disconnect, write error —
/// is recorded in `counters.session_failures` and dropped; every other
/// session keeps serving.
pub(crate) fn event_loop(
    listener: &std::net::TcpListener,
    opts: &ServeOptions,
    serve_batch: &mut dyn FnMut(&[Envelope]) -> (u64, Vec<Json>),
    acc: &mut BTreeMap<u64, GenAccum>,
    counters: &mut ServeCounters,
) -> Result<()> {
    listener.set_nonblocking(true)?;
    let batch_max = opts.batch_max.max(1);
    let mut sessions: Vec<Session> = Vec::new();
    let mut next_id = 0u64;
    let mut shutdown: Option<Instant> = None;
    loop {
        let mut progressed = false;
        if shutdown.is_none() {
            loop {
                match listener.accept() {
                    Ok((stream, _peer)) => {
                        stream.set_nonblocking(true)?;
                        sessions.push(Session {
                            stream,
                            id: next_id,
                            rbuf: Vec::new(),
                            wbuf: Vec::new(),
                            read_closed: false,
                        });
                        counters.sessions += 1;
                        next_id += 1;
                        progressed = true;
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(e) => return Err(e.into()),
                }
            }
        }
        let mut i = 0;
        while i < sessions.len() {
            let s = &mut sessions[i];
            let mut fail: Option<String> = None;
            if !s.read_closed && shutdown.is_none() {
                let mut buf = [0u8; 4096];
                loop {
                    match s.stream.read(&mut buf) {
                        Ok(0) => {
                            s.read_closed = true;
                            break;
                        }
                        Ok(n) => {
                            s.rbuf.extend_from_slice(&buf[..n]);
                            progressed = true;
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                        Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {
                            continue
                        }
                        Err(e) => {
                            fail = Some(format!("read: {e}"));
                            break;
                        }
                    }
                }
            }
            let mut envs: Vec<Envelope> = Vec::new();
            if fail.is_none() {
                while let Some(pos) = s.rbuf.iter().position(|&b| b == b'\n') {
                    let line: Vec<u8> = s.rbuf.drain(..=pos).collect();
                    push_env(&line[..line.len() - 1], &mut envs);
                }
                if s.read_closed && !s.rbuf.is_empty() {
                    // input ended without a trailing newline: the tail
                    // is the final request line (BufRead::lines parity)
                    let tail = std::mem::take(&mut s.rbuf);
                    push_env(&tail, &mut envs);
                }
                if s.rbuf.len() > MAX_LINE {
                    fail = Some(format!(
                        "request line exceeds {MAX_LINE} bytes without a newline"
                    ));
                }
            }
            if fail.is_none() && !envs.is_empty() {
                progressed = true;
                for chunk in envs.chunks(batch_max) {
                    let depth = chunk.len() as u64;
                    let batch_start = Instant::now();
                    let (epoch, responses) = serve_batch(chunk);
                    let a = acc.entry(epoch).or_default();
                    a.batches += 1;
                    a.max_queue_depth = a.max_queue_depth.max(depth);
                    a.first.get_or_insert(batch_start);
                    for (env, resp) in chunk.iter().zip(responses) {
                        let ok = matches!(resp.get("ok"), Some(Json::Bool(true)));
                        counters.requests += 1;
                        a.requests += 1;
                        match &env.req {
                            Ok(ServeRequest::Count { .. }) => a.count_requests += 1,
                            Ok(ServeRequest::Score { .. }) => a.score_requests += 1,
                            Ok(ServeRequest::Shutdown { .. }) => {
                                shutdown.get_or_insert_with(Instant::now);
                            }
                            _ => {}
                        }
                        if !ok {
                            counters.errors += 1;
                            a.errors += 1;
                        }
                        a.note_latency(env.t0.elapsed());
                        s.wbuf.extend_from_slice(resp.dump().as_bytes());
                        s.wbuf.push(b'\n');
                    }
                    a.last = Some(Instant::now());
                }
            }
            if fail.is_none() && !s.wbuf.is_empty() {
                match write_some(&mut s.stream, &s.wbuf) {
                    Ok(n) => {
                        if n > 0 {
                            s.wbuf.drain(..n);
                            progressed = true;
                        }
                    }
                    Err(e) => fail = Some(format!("write: {e}")),
                }
            }
            if let Some(msg) = fail {
                counters.session_failures.push((s.id, msg));
                sessions.remove(i);
                continue;
            }
            if s.read_closed && s.rbuf.is_empty() && s.wbuf.is_empty() {
                sessions.remove(i);
                continue;
            }
            i += 1;
        }
        if let Some(t) = shutdown {
            let draining = sessions.iter().any(|s| !s.wbuf.is_empty());
            if !draining || t.elapsed() > Duration::from_secs(5) {
                return Ok(());
            }
        }
        if !progressed {
            std::thread::sleep(Duration::from_micros(500));
        }
    }
}

/// TCP mode: serve every connected client concurrently through the
/// non-blocking [`event_loop`] (all sessions share the store, so each
/// micro-batch sees the newest published generation).  Runs until a
/// client sends `{"op": "shutdown"}`, then quiesces the delta stream
/// and returns the summary.
pub fn serve_listener(
    engine: ServeEngine,
    listener: std::net::TcpListener,
    opts: &ServeOptions,
) -> Result<ServeSummary> {
    let store = engine.store();
    let feed = opts.feed.clone();
    let pause = opts.delta_pause;
    let log = opts.publish_log.clone();
    let repl = opts.repl.clone();
    let workers = resolve_workers(opts.workers);
    let mut acc: BTreeMap<u64, GenAccum> = BTreeMap::new();
    let mut counters = ServeCounters::default();

    let (engine, publishes, publish_failures, session) =
        std::thread::scope(|scope| {
            let delta =
                scope.spawn(move || drive_deltas(engine, feed, pause, log, repl));
            let session = event_loop(
                &listener,
                opts,
                &mut |batch| {
                    // one generation per micro-batch, same as stdin mode
                    let gen = store.load();
                    let responses = dispatch(&gen, batch, workers, opts);
                    (gen.epoch, responses)
                },
                &mut acc,
                &mut counters,
            );
            let (engine, publishes, failures) =
                delta.join().expect("delta writer panicked");
            (engine, publishes, failures, session)
        });
    session?;

    let rows = acc
        .into_iter()
        .map(|(epoch, a)| {
            let mut r = a.into_row(&opts.database, epoch, workers);
            r.sessions = counters.sessions;
            r
        })
        .collect();
    Ok(ServeSummary {
        rows,
        requests: counters.requests,
        errors: counters.errors,
        publishes,
        publish_failures,
        final_epoch: engine.epoch(),
        final_digest: engine.digest(),
        sessions: counters.sessions,
        session_failures: counters.session_failures,
    })
}

/// Answer one micro-batch from one generation: requests fan out over
/// the reader pool (families routed by cache-key hash; stats, partials
/// and parse errors answered on worker 0), responses in request order.
fn dispatch(
    gen: &Generation,
    batch: &[Envelope],
    workers: usize,
    opts: &ServeOptions,
) -> Vec<Json> {
    let mut assignment: Vec<Vec<usize>> = vec![Vec::new(); workers.max(1)];
    for (i, env) in batch.iter().enumerate() {
        let w = match &env.req {
            Ok(ServeRequest::Count { vars, ctx, .. })
            | Ok(ServeRequest::Score { vars, ctx, .. }) => {
                shard_for_family(vars, ctx, workers)
            }
            _ => 0,
        };
        assignment[w].push(i);
    }
    let run =
        pool::run_shards(batch, &assignment, |_, env| Ok(answer(gen, env, opts)));
    run.results
        .into_iter()
        .map(|r| r.expect("answer() is infallible"))
        .collect()
}

/// Role-specific stats fields: shard coordinates on shards, replication
/// lag/health on followers.  Empty on a plain single-process server, so
/// its stats responses keep the historical byte shape.
fn stats_extras(opts: &ServeOptions) -> Vec<(&'static str, Json)> {
    let mut extra = Vec::new();
    if let Some(cfg) = opts.shard {
        extra.push(("of", Json::num(cfg.of as f64)));
        extra.push(("role", Json::str("shard")));
        extra.push(("shard", Json::num(cfg.index as f64)));
    }
    if let Some(h) = &opts.repl {
        extra.push(("applied_epoch", Json::num(h.applied_epoch() as f64)));
        extra.push(("healthy", Json::Bool(h.healthy())));
        extra.push(("lag", Json::num(h.lag() as f64)));
        extra.push(("leader_epoch", Json::num(h.leader_epoch() as f64)));
        extra.push(("role", Json::str("follower")));
    }
    extra
}

/// Serve one request from one generation; failures become in-protocol
/// error responses (the session keeps going).
fn answer(gen: &Generation, env: &Envelope, opts: &ServeOptions) -> Json {
    match &env.req {
        Err(e) => error_response(0, e),
        Ok(ServeRequest::Count { id, vars, ctx }) => {
            match gen.ct_for_family(vars, ctx) {
                Ok(ct) => count_response(*id, gen.epoch, &ct),
                Err(e) => error_response(*id, &e),
            }
        }
        Ok(ServeRequest::Score { id, vars, ctx, child, n_prime }) => {
            match gen.score_family(vars, ctx, child, *n_prime) {
                Ok(s) => score_response(*id, gen.epoch, s),
                Err(e) => error_response(*id, &e),
            }
        }
        Ok(ServeRequest::Stats { id }) => stats_response_ext(
            *id,
            gen.epoch,
            gen.resident_bytes(),
            gen.digest(),
            stats_extras(opts),
        ),
        Ok(ServeRequest::Shutdown { id }) => shutdown_response(*id, gen.epoch),
        Ok(req @ (ServeRequest::PCount { .. } | ServeRequest::PMarginal { .. })) => {
            crate::serve::shard::answer_partial(gen, opts.shard, req)
        }
    }
}

/// Parse a line-delimited delta stream (one JSON batch per non-empty
/// line) — the `--deltas` wire format of `relcount serve`.  A file
/// holding a single JSON array still parses (one batch).
pub fn parse_delta_stream(text: &str) -> Result<Vec<DeltaBatch>> {
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        out.push(DeltaBatch::parse_json(line).map_err(|e| {
            Error::Data(format!("delta stream line {}: {e}", i + 1))
        })?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::fixtures::university_db;
    use crate::delta::{DeltaOp, MaintainConfig};

    fn lines(reqs: &[ServeRequest]) -> String {
        reqs.iter().map(|r| r.to_json().dump() + "\n").collect()
    }

    fn engine() -> ServeEngine {
        ServeEngine::build(university_db(), MaintainConfig::default()).unwrap()
    }

    fn requests() -> Vec<ServeRequest> {
        crate::serve::protocol::enumerate_requests(&university_db(), 3, 20).unwrap()
    }

    #[test]
    fn static_serving_is_bit_identical_across_worker_counts() {
        let input = lines(&requests());
        let mut outputs = Vec::new();
        for workers in [1usize, 4] {
            let mut out = Vec::new();
            let opts = ServeOptions {
                database: "uw".into(),
                workers,
                ..Default::default()
            };
            let summary = run_serve(
                engine(),
                std::io::Cursor::new(input.clone()),
                &mut out,
                &opts,
            )
            .unwrap();
            assert_eq!(summary.requests, 20);
            assert_eq!(summary.errors, 0);
            assert_eq!(summary.final_epoch, 0);
            outputs.push(out);
        }
        assert_eq!(outputs[0], outputs[1], "responses must not depend on workers");
    }

    #[test]
    fn serving_continues_through_publish_failures() {
        let good = DeltaBatch::new(vec![DeltaOp::DeleteLink { rel: 0, from: 0, to: 0 }]);
        let bad = DeltaBatch::new(vec![DeltaOp::DeleteLink { rel: 0, from: 0, to: 0 }]);
        // `bad` deletes the same pair again -> fails mid-stream
        let after = DeltaBatch::new(vec![DeltaOp::InsertLink {
            rel: 0,
            from: 0,
            to: 0,
            values: vec![3, 2],
        }]);
        let input = lines(&requests());
        let mut out = Vec::new();
        let opts = ServeOptions {
            database: "uw".into(),
            workers: 2,
            feed: DeltaFeed::Batches(vec![good, bad, after]),
            ..Default::default()
        };
        let summary =
            run_serve(engine(), std::io::Cursor::new(input), &mut out, &opts).unwrap();
        assert_eq!(summary.publishes, 2);
        assert_eq!(summary.publish_failures.len(), 1);
        assert_eq!(summary.publish_failures[0].0, 1);
        assert_eq!(summary.final_epoch, 2);
        assert_eq!(summary.errors, 0, "reads never fail through a bad publish");
        // delete + exact reinsert: the final state equals the initial one
        assert_eq!(summary.final_digest, engine().digest());
    }

    #[test]
    fn churn_feed_final_digest_matches_direct_application() {
        let opts = ServeOptions {
            database: "uw".into(),
            workers: 2,
            feed: DeltaFeed::Churn { frac: 0.2, steps: 2, seed: 99 },
            ..Default::default()
        };
        let input = lines(&requests());
        let mut out = Vec::new();
        let summary =
            run_serve(engine(), std::io::Cursor::new(input), &mut out, &opts).unwrap();
        assert_eq!(summary.final_epoch, 2);

        // the same churn applied without any read traffic lands on the
        // same digest: reads are isolated from writes
        let mut direct = engine();
        for i in 0..2u64 {
            let b = churn_batch(direct.db(), 0.2, 99 ^ (i + 1));
            direct.apply_publish(&b).unwrap();
        }
        assert_eq!(summary.final_digest, direct.digest());
        // per-generation rows cover only epochs that served requests
        assert!(!summary.rows.is_empty());
        let served: u64 = summary.rows.iter().map(|r| r.requests).sum();
        assert_eq!(served, summary.requests);
    }

    #[test]
    fn malformed_lines_answer_in_order_and_session_survives() {
        let input = format!(
            "{}\nnot json at all\n{}\n",
            ServeRequest::Stats { id: 7 }.to_json().dump(),
            ServeRequest::Stats { id: 8 }.to_json().dump(),
        );
        let mut out = Vec::new();
        let opts = ServeOptions { database: "uw".into(), ..Default::default() };
        let summary =
            run_serve(engine(), std::io::Cursor::new(input), &mut out, &opts).unwrap();
        assert_eq!(summary.requests, 3);
        assert_eq!(summary.errors, 1);
        let text = String::from_utf8(out).unwrap();
        let ids: Vec<f64> = text
            .lines()
            .map(|l| Json::parse(l).unwrap().get("id").unwrap().as_f64().unwrap())
            .collect();
        assert_eq!(ids, vec![7.0, 0.0, 8.0]);
    }

    #[test]
    fn tcp_sessions_serve_until_shutdown() {
        use std::io::{BufRead, BufReader, Write};
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = std::thread::spawn(move || {
            let mut answers = Vec::new();
            // session 1: one stats request, then EOF
            let mut s1 = std::net::TcpStream::connect(addr).unwrap();
            writeln!(s1, "{}", ServeRequest::Stats { id: 1 }.to_json().dump()).unwrap();
            s1.shutdown(std::net::Shutdown::Write).unwrap();
            let mut line = String::new();
            BufReader::new(&s1).read_line(&mut line).unwrap();
            answers.push(line);
            // session 2: a count, then shutdown
            let mut s2 = std::net::TcpStream::connect(addr).unwrap();
            let req = crate::serve::protocol::enumerate_requests(&university_db(), 3, 1)
                .unwrap()
                .remove(0);
            writeln!(s2, "{}", req.to_json().dump()).unwrap();
            writeln!(s2, "{}", ServeRequest::Shutdown { id: 9 }.to_json().dump())
                .unwrap();
            s2.shutdown(std::net::Shutdown::Write).unwrap();
            let mut r2 = BufReader::new(&s2);
            for _ in 0..2 {
                let mut line = String::new();
                r2.read_line(&mut line).unwrap();
                answers.push(line);
            }
            answers
        });
        let opts = ServeOptions { database: "uw".into(), ..Default::default() };
        let summary = serve_listener(engine(), listener, &opts).unwrap();
        let answers = client.join().unwrap();
        assert_eq!(summary.requests, 3);
        assert_eq!(summary.errors, 0);
        for line in &answers {
            let j = Json::parse(line).unwrap();
            assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "{line}");
        }
    }

    /// Accepts `limit` full response lines, then fails — a
    /// deterministic stand-in for a client that disconnects
    /// mid-response.
    struct FailingWriter {
        lines: usize,
        limit: usize,
    }

    impl Write for FailingWriter {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            if self.lines >= self.limit {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::BrokenPipe,
                    "client gone",
                ));
            }
            self.lines += buf.iter().filter(|&&b| b == b'\n').count();
            Ok(buf.len())
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn failed_session_still_contributes_its_counters() {
        // PR 5 review finding: a session that died mid-stream lost its
        // (requests, errors) from the summary.  The counters now live
        // with the caller, so everything answered before the failure
        // survives the error return.
        let e = engine();
        let store = e.store();
        let input = format!(
            "{}\nnot json\n{}\n{}\n",
            ServeRequest::Stats { id: 1 }.to_json().dump(),
            ServeRequest::Stats { id: 2 }.to_json().dump(),
            ServeRequest::Stats { id: 3 }.to_json().dump(),
        );
        let opts = ServeOptions {
            database: "uw".into(),
            batch_max: 1, // one response per dispatch: the failure point is exact
            ..Default::default()
        };
        let mut acc = BTreeMap::new();
        let mut requests = 0u64;
        let mut errors = 0u64;
        let mut out = FailingWriter { lines: 0, limit: 2 };
        let r = session_loop(
            &store,
            std::io::Cursor::new(input),
            &mut out,
            &opts,
            &mut acc,
            &mut requests,
            &mut errors,
        );
        assert!(r.is_err(), "third response write must fail the session");
        // everything answered before the broken pipe is retained: the
        // ok stats, the parse error, and the response that hit the pipe
        assert_eq!(requests, 3);
        assert_eq!(errors, 1);
    }

    #[test]
    fn adversarial_sessions_fail_typed_while_others_keep_serving() {
        use std::io::{BufRead, BufReader, Read, Write};
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = std::thread::spawn(move || {
            // session 0: an oversized request line (no newline) — the
            // event loop must drop the session, not the server.  The
            // write may hit a broken pipe once the server gives up.
            let mut bad = std::net::TcpStream::connect(addr).unwrap();
            let blob = vec![b'x'; MAX_LINE + 4096];
            let _ = bad.write_all(&blob);
            let _ = bad.flush();
            // wait for the server to close the session: EOF proves the
            // failure was recorded before anything else happens
            let mut sink = Vec::new();
            let _ = bad.read_to_end(&mut sink);
            assert!(sink.is_empty(), "a half line never gets a response");

            // session 1: a truncated request, then disconnect
            // mid-request — the tail is parsed, answered with a typed
            // per-request error, and the session ends cleanly
            let mut trunc = std::net::TcpStream::connect(addr).unwrap();
            trunc.write_all(b"{\"op\": \"sta").unwrap();
            trunc.shutdown(std::net::Shutdown::Write).unwrap();
            let mut line = String::new();
            BufReader::new(&trunc).read_line(&mut line).unwrap();
            let j = Json::parse(&line).unwrap();
            assert_eq!(j.get("ok"), Some(&Json::Bool(false)), "{line}");

            // session 2: valid / garbage / valid interleaved, then
            // shutdown — every line is answered in order
            let mut good = std::net::TcpStream::connect(addr).unwrap();
            writeln!(good, "{}", ServeRequest::Stats { id: 1 }.to_json().dump())
                .unwrap();
            writeln!(good, "no json here").unwrap();
            writeln!(good, "{}", ServeRequest::Stats { id: 2 }.to_json().dump())
                .unwrap();
            writeln!(good, "{}", ServeRequest::Shutdown { id: 3 }.to_json().dump())
                .unwrap();
            good.shutdown(std::net::Shutdown::Write).unwrap();
            let mut oks = Vec::new();
            let mut r = BufReader::new(&good);
            for _ in 0..4 {
                let mut line = String::new();
                r.read_line(&mut line).unwrap();
                let j = Json::parse(&line).unwrap();
                oks.push(j.get("ok") == Some(&Json::Bool(true)));
            }
            oks
        });
        let opts = ServeOptions { database: "uw".into(), ..Default::default() };
        let summary = serve_listener(engine(), listener, &opts).unwrap();
        let oks = client.join().unwrap();
        assert_eq!(oks, vec![true, false, true, true]);
        assert_eq!(summary.sessions, 3, "every accepted session is accounted");
        assert_eq!(summary.session_failures.len(), 1);
        assert!(
            summary.session_failures[0].1.contains("exceeds"),
            "{:?}",
            summary.session_failures
        );
        // truncated tail + garbage line are per-request errors, not
        // session failures
        assert_eq!(summary.requests, 5);
        assert_eq!(summary.errors, 2);
    }

    #[test]
    fn fragmented_request_lines_reassemble_across_reads() {
        use std::io::{BufRead, BufReader, Write};
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = std::thread::spawn(move || {
            let mut s = std::net::TcpStream::connect(addr).unwrap();
            // one request drip-fed over three writes with pauses: the
            // session buffer must splice it back together
            let line = ServeRequest::Stats { id: 5 }.to_json().dump() + "\n";
            let bytes = line.as_bytes();
            for chunk in bytes.chunks(bytes.len() / 3 + 1) {
                s.write_all(chunk).unwrap();
                s.flush().unwrap();
                std::thread::sleep(Duration::from_millis(5));
            }
            writeln!(s, "{}", ServeRequest::Shutdown { id: 6 }.to_json().dump())
                .unwrap();
            s.shutdown(std::net::Shutdown::Write).unwrap();
            let mut ids = Vec::new();
            let mut r = BufReader::new(&s);
            for _ in 0..2 {
                let mut line = String::new();
                r.read_line(&mut line).unwrap();
                let j = Json::parse(&line).unwrap();
                assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "{line}");
                ids.push(j.get("id").unwrap().as_f64().unwrap() as u64);
            }
            ids
        });
        let opts = ServeOptions { database: "uw".into(), ..Default::default() };
        let summary = serve_listener(engine(), listener, &opts).unwrap();
        assert_eq!(client.join().unwrap(), vec![5, 6]);
        assert_eq!(summary.requests, 2);
        assert_eq!(summary.errors, 0);
        assert_eq!(summary.sessions, 1);
        assert!(summary.session_failures.is_empty());
    }

    #[test]
    fn delta_stream_parses_line_delimited_batches() {
        let b1 = DeltaBatch::new(vec![DeltaOp::InsertEntity { et: 0, values: vec![1] }]);
        let b2 = DeltaBatch::new(vec![DeltaOp::DeleteLink { rel: 0, from: 0, to: 0 }]);
        let text = format!("{}\n\n{}\n", b1.to_json().dump(), b2.to_json().dump());
        let parsed = parse_delta_stream(&text).unwrap();
        assert_eq!(parsed, vec![b1, b2]);
        assert!(parse_delta_stream("nope\n").is_err());
    }
}
