//! Shard role of scale-out serving: a `relcount shard` process is a
//! full serve engine (own generations, own `--data-dir` recovery) that
//! additionally answers the shard-internal `pcount`/`pmarginal` ops
//! with **partial tables** — only the join rows / entities whose anchor
//! the shard owns under [`entity_shard`].  The router merges the `of`
//! partials; positives sum integer-exactly because anchor ownership
//! partitions every chain's join rows (DESIGN.md §3i).
//!
//! Every shard of a topology must be loaded from the **same database**
//! (and fed the same deltas): the slice is a property of the query, not
//! of the loaded data, so recovery, churn and replication all compose
//! with sharding unchanged.

use crate::db::query::{groupby_entity_filtered, partial_chain_ct, JoinStats};
use crate::error::Error;
use crate::serve::protocol::{error_response, partial_response, ServeRequest};
use crate::serve::snapshot::Generation;
use crate::util::json::Json;

/// Which slice of the entity-hash partition this process owns.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardConfig {
    /// This shard's index, `0 <= index < of`.
    pub index: usize,
    /// Total shard count of the topology.
    pub of: usize,
}

/// Answer a `pcount`/`pmarginal` request against one generation.  A
/// process without a shard role rejects them typed (`Error::Route`), so
/// a misrouted partial request can never be mistaken for a full count.
/// The response carries the partial table's own digest plus the
/// generation digest (`state`) the router cross-checks across shards.
pub fn answer_partial(
    gen: &Generation,
    cfg: Option<ShardConfig>,
    req: &ServeRequest,
) -> Json {
    let cfg = match cfg {
        Some(c) => c,
        None => {
            return error_response(
                req.id(),
                &Error::Route(
                    "this server is not a shard (start it with \
                     `relcount shard --index I --of K`)"
                        .into(),
                ),
            )
        }
    };
    let db = gen.db();
    match req {
        ServeRequest::PCount { id, chain, vars } => {
            let mut stats = JoinStats::default();
            match partial_chain_ct(db, chain, vars, cfg.index, cfg.of, &mut stats) {
                Ok(ct) => partial_response(
                    *id,
                    gen.epoch,
                    gen.digest(),
                    cfg.index,
                    cfg.of,
                    &ct,
                ),
                Err(e) => error_response(*id, &e),
            }
        }
        ServeRequest::PMarginal { id, et, vars } => {
            match groupby_entity_filtered(db, *et, vars, Some((cfg.index, cfg.of))) {
                Ok(ct) => partial_response(
                    *id,
                    gen.epoch,
                    gen.digest(),
                    cfg.index,
                    cfg.of,
                    &ct,
                ),
                Err(e) => error_response(*id, &e),
            }
        }
        other => error_response(
            other.id(),
            &Error::Route("answer_partial: not a partial request".into()),
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ct::cttable::CtTable;
    use crate::db::fixtures::university_db;
    use crate::db::query::positive_chain_ct;
    use crate::delta::MaintainConfig;
    use crate::meta::rvar::RVar;
    use crate::serve::engine::ServeEngine;

    fn generation() -> std::sync::Arc<Generation> {
        ServeEngine::build(university_db(), MaintainConfig::default())
            .unwrap()
            .store()
            .load()
    }

    #[test]
    fn non_shards_reject_partial_requests_typed() {
        let gen = generation();
        let req = ServeRequest::PCount { id: 3, chain: vec![0], vars: vec![] };
        let resp = answer_partial(&gen, None, &req);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
        let msg = resp.get("error").unwrap().as_str().unwrap().to_string();
        assert!(msg.starts_with("route error:"), "{msg}");
    }

    #[test]
    fn shard_partials_reassemble_the_full_table() {
        let gen = generation();
        let db = university_db();
        let vars = vec![RVar::EntityAttr { et: 1, attr: 0 }];
        let mut stats = JoinStats::default();
        let full = positive_chain_ct(&db, &[0, 1], &vars, &mut stats).unwrap();
        let mut acc = CtTable::new(&db.schema, vars.clone()).unwrap();
        for index in 0..2usize {
            let req = ServeRequest::PCount {
                id: index as u64,
                chain: vec![0, 1],
                vars: vars.clone(),
            };
            let cfg = ShardConfig { index, of: 2 };
            let resp = answer_partial(&gen, Some(cfg), &req);
            assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
            assert_eq!(resp.get("shard").unwrap().as_f64(), Some(index as f64));
            // rebuild the wire rows and fold them in, as the router does
            for row in resp.get("rows").unwrap().as_arr().unwrap() {
                let cells = row.as_arr().unwrap();
                let vals: Vec<u32> = cells[..cells.len() - 1]
                    .iter()
                    .map(|v| v.as_f64().unwrap() as u32)
                    .collect();
                let count = cells[cells.len() - 1].as_f64().unwrap() as i128;
                acc.add(&vals, count).unwrap();
            }
        }
        assert_eq!(acc.digest(), full.digest());
    }
}
