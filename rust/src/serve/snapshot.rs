//! Immutable serving generations and the epoch-versioned publish point.
//!
//! A [`Generation`] is a fully-built, frozen copy of the counting
//! engine's state — database, lattice, residency plan, and every
//! resident ct-table — stamped with an epoch number.  Readers serve
//! `ct` queries from a generation through shared references only
//! (the same `serve_one` code path the parallel coordinator and the
//! maintained caches use), so any number of threads can answer queries
//! from generation N concurrently with zero synchronization.
//!
//! The [`SnapshotStore`] is the single point where generations change
//! hands: the delta writer publishes generation N+1 as one atomic
//! `Arc` swap, and readers [`SnapshotStore::load`] whichever generation
//! is current.  A reader that loaded generation N keeps serving from it
//! for as long as it holds the `Arc` — it never observes a half-applied
//! batch, because batches are applied to a private clone and only
//! published once fully (and verifiably) applied.  The only lock in the
//! system guards the pointer swap itself (a `RwLock<Arc<_>>` held for
//! nanoseconds); all count computation is lock-free.

use std::sync::{Arc, RwLock};

use crate::coordinator::parallel::serve_one;
use crate::ct::cttable::CtTable;
use crate::db::catalog::Database;
use crate::db::query::JoinStats;
use crate::error::{Error, Result};
use crate::estimate::plan::CountPlan;
use crate::lattice::Lattice;
use crate::learn::score::bdeu_from_ct;
use crate::meta::rvar::RVar;
use crate::strategies::cache::{digest_caches, CtCache};
use crate::strategies::StrategyKind;

/// One immutable, fully-built state of the counting engine.
///
/// Construct via [`crate::delta::MaintainedCounts::snapshot`]; serve
/// with [`Generation::ct_for_family`] / [`Generation::score_family`]
/// from any thread.
pub struct Generation {
    /// Monotonic version: the number of delta batches applied since the
    /// initial build (epoch 0).
    pub epoch: u64,
    db: Database,
    lattice: Lattice,
    plan: CountPlan,
    positive: CtCache,
    complete: CtCache,
    /// Content digest of the resident caches, computed once at freeze
    /// time (same algorithm as [`crate::delta::MaintainedCounts::digest`]).
    digest: u64,
}

impl Generation {
    /// Assemble a generation from already-cloned parts (the
    /// [`crate::delta::MaintainedCounts::snapshot`] path).
    pub(crate) fn from_parts(
        epoch: u64,
        db: Database,
        lattice: Lattice,
        plan: CountPlan,
        positive: CtCache,
        complete: CtCache,
    ) -> Generation {
        let digest = digest_caches(&[(0u8, &positive), (1u8, &complete)]);
        Generation { epoch, db, lattice, plan, positive, complete, digest }
    }

    pub fn db(&self) -> &Database {
        &self.db
    }

    /// Digest of the resident caches — equal to the writer state this
    /// generation was frozen from.
    pub fn digest(&self) -> u64 {
        self.digest
    }

    /// Exact bytes held by this generation's resident tables.
    pub fn resident_bytes(&self) -> usize {
        self.positive.bytes() + self.complete.bytes()
    }

    /// Serve one family's complete ct-table from this generation —
    /// `&self` only, so readers need no lock and no coordination.  The
    /// code path is `serve_one` in ADAPTIVE mode, identical to the
    /// coordinator and the maintained caches, so served counts are
    /// bit-identical to every fresh strategy on this generation's data.
    pub fn ct_for_family(&self, vars: &[RVar], ctx_pops: &[usize]) -> Result<CtTable> {
        Ok(self.serve(vars, ctx_pops)?.0)
    }

    /// [`Generation::ct_for_family`] plus the query counters the serve
    /// executed (fallback joins for unplanned chains).
    pub fn serve(
        &self,
        vars: &[RVar],
        ctx_pops: &[usize],
    ) -> Result<(CtTable, JoinStats)> {
        let served = serve_one(
            &self.db,
            &self.lattice,
            &self.positive,
            &self.complete,
            StrategyKind::Adaptive,
            Some(&self.plan),
            vars,
            ctx_pops,
        )?;
        Ok((served.ct, served.stats))
    }

    /// BDeu family score served from this generation: count the family,
    /// then score `child` against the remaining variables as parents.
    pub fn score_family(
        &self,
        vars: &[RVar],
        ctx_pops: &[usize],
        child: &RVar,
        n_prime: f64,
    ) -> Result<f64> {
        if !vars.contains(child) {
            return Err(Error::Learn(format!(
                "score child {child:?} is not among the family variables"
            )));
        }
        let ct = self.ct_for_family(vars, ctx_pops)?;
        bdeu_from_ct(&ct, child, n_prime)
    }
}

/// The epoch-versioned publish point: readers load the current
/// generation, the writer swaps in the next one atomically.
pub struct SnapshotStore {
    cur: RwLock<Arc<Generation>>,
}

impl SnapshotStore {
    pub fn new(initial: Generation) -> SnapshotStore {
        SnapshotStore { cur: RwLock::new(Arc::new(initial)) }
    }

    /// The current generation.  Cheap (an `Arc` clone under a read
    /// lock held only for the clone); the returned generation stays
    /// valid — and keeps serving consistent counts — however many
    /// publishes happen after.
    pub fn load(&self) -> Arc<Generation> {
        self.cur.read().expect("snapshot store poisoned").clone()
    }

    /// Epoch of the current generation.
    pub fn epoch(&self) -> u64 {
        self.cur.read().expect("snapshot store poisoned").epoch
    }

    /// Atomically replace the current generation.  Panics (in debug) if
    /// the epoch does not advance — publishes must be monotonic.
    pub fn publish(&self, next: Generation) -> u64 {
        let epoch = next.epoch;
        let mut cur = self.cur.write().expect("snapshot store poisoned");
        debug_assert!(
            epoch > cur.epoch,
            "publish must advance the epoch ({} -> {epoch})",
            cur.epoch
        );
        *cur = Arc::new(next);
        epoch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ct::mobius::brute_force_complete;
    use crate::db::fixtures::university_db;
    use crate::delta::{MaintainConfig, MaintainedCounts};

    fn family() -> Vec<RVar> {
        vec![
            RVar::RelInd { rel: 0 },
            RVar::RelAttr { rel: 0, attr: 1 },
            RVar::EntityAttr { et: 1, attr: 0 },
        ]
    }

    #[test]
    fn generation_serves_brute_force_counts_immutably() {
        let db = university_db();
        let m = MaintainedCounts::build(db.clone(), MaintainConfig::default()).unwrap();
        let g = m.snapshot(0).unwrap();
        assert_eq!(g.epoch, 0);
        assert_eq!(g.digest(), m.digest());
        let brute = brute_force_complete(&db, &family(), &[0, 1]).unwrap();
        // repeated serves from &self: no state mutates, answers repeat
        for _ in 0..2 {
            let ct = g.ct_for_family(&family(), &[0, 1]).unwrap();
            assert_eq!(ct.n_rows(), brute.n_rows());
            for (v, c) in brute.iter_rows() {
                assert_eq!(ct.get(&v).unwrap(), c);
            }
        }
    }

    #[test]
    fn score_requires_child_in_family() {
        let db = university_db();
        let m = MaintainedCounts::build(db, MaintainConfig::default()).unwrap();
        let g = m.snapshot(0).unwrap();
        let child = RVar::EntityAttr { et: 1, attr: 0 };
        let s = g.score_family(&family(), &[0, 1], &child, 1.0).unwrap();
        assert!(s.is_finite());
        let stranger = RVar::EntityAttr { et: 0, attr: 0 };
        assert!(g.score_family(&family(), &[0, 1], &stranger, 1.0).is_err());
    }

    #[test]
    fn store_load_survives_publish() {
        let db = university_db();
        let m = MaintainedCounts::build(db, MaintainConfig::default()).unwrap();
        let store = SnapshotStore::new(m.snapshot(0).unwrap());
        let held = store.load();
        assert_eq!(store.epoch(), 0);
        store.publish(m.snapshot(1).unwrap());
        assert_eq!(store.epoch(), 1);
        // the reader's generation is unaffected by the publish
        assert_eq!(held.epoch, 0);
        assert!(held.ct_for_family(&family(), &[0, 1]).is_ok());
        assert_eq!(store.load().epoch, 1);
    }
}
