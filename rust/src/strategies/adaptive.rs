//! ADAPTIVE — Algorithm 3 generalized into a cost-model-driven planner.
//!
//! Where HYBRID hard-codes one global answer to the pre-vs-post counting
//! trade-off, ADAPTIVE decides **per lattice point** from estimated
//! costs, under an explicit memory budget
//! ([`StrategyConfig::mem_budget`]):
//!
//! 1. a [`CountPlan`] ranks lattice points by estimated
//!    `reuse × join-cost / bytes` (sampling-based cardinality
//!    estimation, [`crate::estimate`]) and greedily fills the budget —
//!    first with positive pre-counts (the HYBRID axis), then with
//!    complete pre-counts (the PRECOUNT axis);
//! 2. `prepare` builds exactly the planned tables;
//! 3. serving projects from whatever is planned and **falls back to
//!    fresh joins** (plus family-level Möbius) for the rest, so every
//!    budget point — 0 (pure ONDEMAND) through HYBRID's operating point
//!    to unlimited (pure PRECOUNT) — serves **bit-identical** counts.
//!    Only *where* counts are computed changes; `exp planner` sweeps the
//!    spectrum.

use crate::ct::cttable::CtTable;
use crate::ct::mobius::{mobius_complete, ChainSource};
use crate::ct::project::project;
use crate::db::catalog::Database;
use crate::db::query::{groupby_entity, positive_chain_ct, JoinStats};
use crate::db::schema::Schema;
use crate::error::{Error, Result};
use crate::estimate::plan::CountPlan;
use crate::lattice::Lattice;
use crate::meta::rvar::RVar;
use crate::metrics::memory::MemTracker;
use crate::metrics::timing::{Deadline, Phase, PhaseTimer};
use crate::strategies::cache::{digest_caches, CtCache};
use crate::strategies::common::{
    entity_key, lp_key, narrow_to_ctx, run_positive_task, var_pops, var_rels,
    LatticeCtx, PositiveTask, TimedSource,
};
use crate::strategies::precount::Precount;
use crate::strategies::traits::{CountingStrategy, StrategyConfig, StrategyReport};

/// A [`ChainSource`] that serves positive counts by projection from the
/// planned pre-count cache and silently falls back to fresh joins for
/// unplanned (or out-of-lattice) chains — the serving half of ADAPTIVE.
///
/// Reads the cache through [`CtCache::peek`] (the cache is frozen after
/// `prepare`), so the same source type works for the sequential strategy
/// and the parallel coordinator's worker shards.
pub struct PlannedSource<'a> {
    pub db: &'a Database,
    pub lattice: &'a Lattice,
    pub plan: &'a CountPlan,
    pub cache: &'a CtCache,
    /// Fallback-join counters (merged into the strategy's totals).
    pub stats: JoinStats,
}

impl ChainSource for PlannedSource<'_> {
    fn positive_chain_ct(&mut self, chain: &[usize], vars: &[RVar]) -> Result<CtTable> {
        if let Some(p) = self.lattice.point(chain) {
            if self.plan.positive_planned(p.id) {
                let key = lp_key(&p.rels, &p.attr_vars, &p.pops);
                if let Some(full) = self.cache.peek(&key) {
                    return project(full, vars);
                }
            }
        }
        // Unplanned chain (or beyond the lattice): post-count it.
        positive_chain_ct(self.db, chain, vars, &mut self.stats)
    }

    fn entity_marginal(&mut self, et: usize, vars: &[RVar]) -> Result<CtTable> {
        if self.plan.marginals {
            if let Some(full) = self.cache.peek(&entity_key(et)) {
                return project(full, vars);
            }
        }
        self.stats.entity_queries += 1;
        groupby_entity(self.db, et, vars)
    }

    fn schema(&self) -> &Schema {
        &self.db.schema
    }

    fn population(&self, et: usize) -> i128 {
        self.db.population(et) as i128
    }
}

/// The ADAPTIVE strategy.
pub struct Adaptive<'a> {
    db: &'a Database,
    cfg: StrategyConfig,
    ctx: LatticeCtx,
    plan: CountPlan,
    /// Planned positive lattice ct-tables + entity marginals.
    positive: CtCache,
    /// Planned complete lattice ct-tables.
    complete: CtCache,
    /// Post-counting cache of family ct-tables.
    family_cache: CtCache,
    timer: PhaseTimer,
    deadline: Deadline,
    join_stats: JoinStats,
    mem: MemTracker,
    families_served: u64,
    rows_generated: u64,
    complete_hits: u64,
    prepared: bool,
}

impl<'a> Adaptive<'a> {
    /// Metadata phase *and* planning run here: the plan is a pure
    /// function of (database, lattice, estimator config, budget), so a
    /// parallel coordinator building the same inputs gets the same plan.
    pub fn new(db: &'a Database, cfg: StrategyConfig) -> Result<Self> {
        let deadline = Deadline::new(cfg.budget);
        let mut timer = PhaseTimer::default();
        let ctx = LatticeCtx::build(db, cfg.max_chain_length, &mut timer)?;
        let plan = timer.time(Phase::Metadata, || {
            CountPlan::build(db, &ctx.lattice, cfg.estimator, cfg.mem_budget)
        })?;
        Ok(Adaptive {
            db,
            cfg,
            ctx,
            plan,
            positive: CtCache::new(),
            complete: CtCache::new(),
            family_cache: CtCache::new(),
            timer,
            deadline,
            join_stats: JoinStats::default(),
            mem: MemTracker::default(),
            families_served: 0,
            rows_generated: 0,
            complete_hits: 0,
            prepared: false,
        })
    }

    /// The plan driving this instance (inspection / the planner sweep).
    pub fn plan(&self) -> &CountPlan {
        &self.plan
    }

    /// The planned subset of the positive-phase task list, in canonical
    /// order (entity marginals first iff planned, then planned points by
    /// ascending id) — shared with the parallel coordinator and the
    /// delta maintenance subsystem ([`crate::delta`]) so all three fill
    /// identical caches.
    pub fn planned_positive_tasks(
        db: &Database,
        plan: &CountPlan,
    ) -> Vec<PositiveTask> {
        let mut tasks = Vec::new();
        if plan.marginals {
            tasks.extend((0..db.schema.entities.len()).map(PositiveTask::Entity));
        }
        tasks.extend(
            (0..plan.levels.len())
                .filter(|&id| plan.positive_planned(id))
                .map(PositiveTask::Point),
        );
        tasks
    }

    /// The planned complete-phase point ids, ascending.
    pub fn planned_complete_points(plan: &CountPlan) -> Vec<usize> {
        (0..plan.levels.len()).filter(|&id| plan.complete_planned(id)).collect()
    }
}

impl CountingStrategy for Adaptive<'_> {
    fn name(&self) -> &'static str {
        "ADAPTIVE"
    }

    /// Build exactly the planned tables: positive fill for planned
    /// points (+ marginals), then complete tables for the
    /// complete-planned points.
    fn prepare(&mut self) -> Result<()> {
        if self.prepared {
            return Ok(());
        }
        for task in Self::planned_positive_tasks(self.db, &self.plan) {
            self.deadline.check(match task {
                PositiveTask::Entity(_) => "positive ct (entity)",
                PositiveTask::Point(_) => "positive ct (lattice)",
            })?;
            let (key, t) = self.timer.time(Phase::Positive, || {
                run_positive_task(self.db, &self.ctx, task, &mut self.join_stats)
            })?;
            self.positive.insert(key, t);
        }
        for id in Self::planned_complete_points(&self.plan) {
            self.deadline.check("negative ct (lattice)")?;
            let p = self.ctx.lattice.points[id].clone();
            let vars = p.all_vars();
            let (complete, stats) = {
                let mut src = PlannedSource {
                    db: self.db,
                    lattice: &self.ctx.lattice,
                    plan: &self.plan,
                    cache: &self.positive,
                    stats: JoinStats::default(),
                };
                let ct = self.timer.time(Phase::Negative, || {
                    mobius_complete(&mut src, &vars, &p.pops)
                })?;
                (ct, src.stats)
            };
            self.join_stats.merge(&stats);
            self.rows_generated += complete.n_rows() as u64;
            self.complete.insert(Precount::complete_key(&p), complete);
        }
        self.prepared = true;
        Ok(())
    }

    fn ct_for_family(&mut self, vars: &[RVar], ctx_pops: &[usize]) -> Result<CtTable> {
        if !self.prepared {
            self.prepare()?;
        }
        self.deadline.check("family count (adaptive)")?;
        self.families_served += 1;

        // Complete-planned covering point: serve by projection, exactly
        // PRECOUNT's path (no family cache — the projection is cheaper
        // than a lookup-plus-clone of a cached copy).
        let rels = var_rels(vars);
        if !rels.is_empty() {
            let vpops = var_pops(&self.db.schema, vars);
            if let Some(p) = self.ctx.lattice.covering_point(&rels, &vpops) {
                if self.plan.complete_planned(p.id) {
                    let p = p.clone();
                    let key = Precount::complete_key(&p);
                    let full = self
                        .complete
                        .get(&key)
                        .ok_or_else(|| {
                            Error::Strategy("complete ct missing (prepare?)".into())
                        })?;
                    let mut ct =
                        self.timer.time(Phase::Positive, || project(full, vars))?;
                    narrow_to_ctx(self.db, &mut ct, &p.pops, ctx_pops, vars)?;
                    self.complete_hits += 1;
                    self.mem.observe_transient(ct.bytes());
                    return Ok(ct);
                }
            }
        }

        // Otherwise: family-level Möbius over planned positives with
        // fresh-join fallback (the HYBRID/ONDEMAND axis).
        let key = CtCache::key(vars, ctx_pops);
        if self.cfg.family_cache {
            if let Some(hit) = self.family_cache.get(&key) {
                return Ok(hit.clone());
            }
        }
        let t0 = std::time::Instant::now();
        let mut src = PlannedSource {
            db: self.db,
            lattice: &self.ctx.lattice,
            plan: &self.plan,
            cache: &self.positive,
            stats: JoinStats::default(),
        };
        let ct = {
            let mut timed = TimedSource::new(&mut src);
            let ct = mobius_complete(&mut timed, vars, ctx_pops)?;
            self.timer.add(Phase::Positive, timed.positive_elapsed);
            self.timer
                .add(Phase::Negative, t0.elapsed().saturating_sub(timed.positive_elapsed));
            ct
        };
        self.join_stats.merge(&src.stats);
        self.rows_generated += ct.n_rows() as u64;
        self.mem.observe_transient(ct.bytes());
        if self.cfg.family_cache {
            self.family_cache.insert(key, ct.clone());
        }
        Ok(ct)
    }

    fn report(&self) -> StrategyReport {
        let mut peak = self.mem;
        peak.merge_peak(&self.positive.mem);
        peak.peak_bytes = peak.peak_bytes.max(
            self.positive.mem.current_bytes
                + self.complete.mem.peak_bytes
                + self.family_cache.mem.peak_bytes,
        );
        StrategyReport {
            name: self.name().into(),
            timing: self.timer,
            join_stats: self.join_stats,
            cache_bytes: self.positive.bytes()
                + self.complete.bytes()
                + self.family_cache.bytes(),
            peak_ct_bytes: peak.peak_bytes,
            ct_rows_generated: self.rows_generated,
            families_served: self.families_served,
            cache_hits: self.family_cache.hits + self.complete_hits,
            cache_misses: self.family_cache.misses,
            planned_positive: self.plan.planned_positive_count(),
            planned_complete: self.plan.planned_complete_count(),
            plan_est_bytes: self.plan.est_spent_bytes,
            estimator_walks: self.plan.walks,
        }
    }

    fn cache_digest(&self) -> u64 {
        digest_caches(&[
            (0, &self.positive),
            (1, &self.complete),
            (2, &self.family_cache),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ct::mobius::brute_force_complete;
    use crate::db::fixtures::university_db;

    fn family() -> Vec<RVar> {
        vec![
            RVar::RelInd { rel: 0 },
            RVar::RelAttr { rel: 0, attr: 1 },
            RVar::EntityAttr { et: 1, attr: 0 },
        ]
    }

    fn adaptive(db: &Database, budget: Option<u64>) -> Adaptive<'_> {
        let cfg = StrategyConfig { mem_budget: budget, ..Default::default() };
        Adaptive::new(db, cfg).unwrap()
    }

    #[test]
    fn zero_budget_counts_match_brute_force() {
        let db = university_db();
        let mut s = adaptive(&db, Some(0));
        s.prepare().unwrap();
        assert_eq!(s.report().planned_positive, 0);
        let ct = s.ct_for_family(&family(), &[0, 1]).unwrap();
        let brute = brute_force_complete(&db, &family(), &[0, 1]).unwrap();
        assert_eq!(ct.n_rows(), brute.n_rows());
        for (v, c) in brute.iter_rows() {
            assert_eq!(ct.get(&v).unwrap(), c);
        }
        // pure post-counting: the serve executed fresh joins
        assert!(s.report().join_stats.chain_queries > 0);
    }

    #[test]
    fn unlimited_budget_counts_match_brute_force() {
        let db = university_db();
        let mut s = adaptive(&db, None);
        s.prepare().unwrap();
        let rep = s.report();
        assert_eq!(rep.planned_complete as usize, s.ctx.lattice.len());
        let joins_after_prepare = s.join_stats.chain_queries;
        let ct = s.ct_for_family(&family(), &[0, 1]).unwrap();
        let brute = brute_force_complete(&db, &family(), &[0, 1]).unwrap();
        for (v, c) in brute.iter_rows() {
            assert_eq!(ct.get(&v).unwrap(), c);
        }
        // fully pre-counted: serving never joins
        assert_eq!(s.join_stats.chain_queries, joins_after_prepare);
        assert_eq!(s.report().cache_hits, 1); // served by projection
    }

    #[test]
    fn hybrid_budget_prepares_positives_only() {
        let db = university_db();
        let probe = adaptive(&db, None);
        let hb = probe.plan().hybrid_budget();
        let mut s = adaptive(&db, Some(hb));
        s.prepare().unwrap();
        let rep = s.report();
        assert_eq!(rep.planned_positive as usize, s.ctx.lattice.len());
        assert_eq!(rep.planned_complete, 0);
        let joins_after_prepare = s.join_stats.chain_queries;
        let ct = s.ct_for_family(&family(), &[0, 1]).unwrap();
        let brute = brute_force_complete(&db, &family(), &[0, 1]).unwrap();
        for (v, c) in brute.iter_rows() {
            assert_eq!(ct.get(&v).unwrap(), c);
        }
        // HYBRID-equivalent: projections only during search
        assert_eq!(s.join_stats.chain_queries, joins_after_prepare);
    }

    #[test]
    fn partial_budget_mixes_pre_and_post() {
        let db = university_db();
        let probe = adaptive(&db, None);
        let half = probe.plan().hybrid_budget() / 2;
        let mut s = adaptive(&db, Some(half));
        s.prepare().unwrap();
        let rep = s.report();
        assert!(rep.planned_positive > 0, "half the hybrid budget plans something");
        assert!((rep.planned_positive as usize) < s.ctx.lattice.len());
        // counts stay exact regardless
        for vars in [family(), vec![RVar::RelInd { rel: 0 }, RVar::RelInd { rel: 1 }]] {
            let ctx: Vec<usize> = if vars.len() == 2 { vec![0, 1, 2] } else { vec![0, 1] };
            let ct = s.ct_for_family(&vars, &ctx).unwrap();
            let brute = brute_force_complete(&db, &vars, &ctx).unwrap();
            for (v, c) in brute.iter_rows() {
                assert_eq!(ct.get(&v).unwrap(), c, "{vars:?}");
            }
        }
    }

    #[test]
    fn family_cache_hits_on_revisit() {
        let db = university_db();
        let mut s = adaptive(&db, Some(0));
        s.ct_for_family(&family(), &[0, 1]).unwrap();
        let joins = s.join_stats.chain_queries;
        s.ct_for_family(&family(), &[0, 1]).unwrap();
        assert_eq!(s.join_stats.chain_queries, joins);
        assert_eq!(s.report().cache_hits, 1);
    }

    #[test]
    fn report_carries_plan_accounting() {
        let db = university_db();
        let mut s = adaptive(&db, None);
        s.prepare().unwrap();
        let rep = s.report();
        assert_eq!(rep.name, "ADAPTIVE");
        assert!(rep.plan_est_bytes > 0);
        assert_eq!(rep.planned_positive, rep.planned_complete);
        assert!(rep.timing.metadata > std::time::Duration::ZERO);
    }
}
