//! Count caches with exact byte accounting and hit statistics.

use crate::error::{Error, Result};
use crate::util::fxhash::FxHashMap;

use crate::ct::cttable::CtTable;
use crate::meta::rvar::RVar;
use crate::metrics::memory::MemTracker;

/// Cache key: (variables in canonical order, population context).
pub type CacheKey = (Vec<RVar>, Vec<usize>);

/// A ct-table cache.
#[derive(Clone, Debug, Default)]
pub struct CtCache {
    map: FxHashMap<CacheKey, CtTable>,
    pub mem: MemTracker,
    pub hits: u64,
    pub misses: u64,
    /// Total rows over all tables ever inserted (Table 5 metric).
    pub rows_inserted: u64,
    /// Cells touched by in-place delta maintenance
    /// ([`CtCache::apply_delta`]) — the churn workload's cost metric.
    pub cells_deltaed: u64,
}

impl CtCache {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn key(vars: &[RVar], ctx: &[usize]) -> CacheKey {
        (vars.to_vec(), ctx.to_vec())
    }

    pub fn get(&mut self, key: &CacheKey) -> Option<&CtTable> {
        if self.map.contains_key(key) {
            self.hits += 1;
            self.map.get(key)
        } else {
            self.misses += 1;
            None
        }
    }

    /// Peek without touching hit statistics.
    pub fn peek(&self, key: &CacheKey) -> Option<&CtTable> {
        self.map.get(key)
    }

    pub fn insert(&mut self, key: CacheKey, table: CtTable) {
        self.rows_inserted += table.n_rows() as u64;
        self.mem.add(table.bytes());
        if let Some(old) = self.map.insert(key, table) {
            self.mem.sub(old.bytes());
        }
    }

    /// Merge a signed delta table into a resident entry in place
    /// (cell-level add/sub; zero cells compact away — no tombstones),
    /// keeping the byte accounting exact.  Errors if the entry is absent
    /// — delta maintenance must never silently materialize tables.
    pub fn apply_delta(&mut self, key: &CacheKey, delta: &CtTable) -> Result<()> {
        let entry = self.map.get_mut(key).ok_or_else(|| {
            Error::Strategy(format!("apply_delta: no resident table for {key:?}"))
        })?;
        let old_bytes = entry.bytes();
        entry.add_table(delta)?;
        let new_bytes = entry.bytes();
        self.mem.sub(old_bytes);
        self.mem.add(new_bytes);
        self.cells_deltaed += delta.n_rows() as u64;
        Ok(())
    }

    /// Drop an entry (invalidate-and-recount path), returning it.
    pub fn remove(&mut self, key: &CacheKey) -> Option<CtTable> {
        let old = self.map.remove(key)?;
        self.mem.sub(old.bytes());
        Some(old)
    }

    /// Iterate entries in unspecified order (digests sort keys first).
    pub fn iter(&self) -> impl Iterator<Item = (&CacheKey, &CtTable)> {
        self.map.iter()
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn bytes(&self) -> usize {
        self.mem.current_bytes
    }

    pub fn clear(&mut self) {
        self.map.clear();
        self.mem.current_bytes = 0;
    }
}

/// Deterministic digest over tagged caches: entries in sorted cache-key
/// order, rows in sorted flat-key order.  Shared by
/// [`crate::delta::MaintainedCounts::digest`] and the serving
/// generations ([`crate::serve`]), so a published snapshot hashes
/// identically to the writer state it was cloned from — the
/// bit-identity witness used by the churn experiment, the differential
/// tests and the serve smoke.
pub fn digest_caches(caches: &[(u8, &CtCache)]) -> u64 {
    use std::hash::{Hash, Hasher};
    // Global (tag, key) sort across all passed caches: several caches
    // with the same tag digest as their union, so a sharded family
    // cache (one shard per coordinator worker) hashes identically for
    // every worker count — and to the sequential strategy's single
    // cache.  Distinct-tag inputs hash exactly as before.
    let mut entries: Vec<(u8, &CacheKey, &CtTable)> = caches
        .iter()
        .flat_map(|&(tag, cache)| cache.iter().map(move |(k, t)| (tag, k, t)))
        .collect();
    entries.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
    let mut h = crate::util::fxhash::FxHasher::default();
    for (tag, key, t) in entries {
        tag.hash(&mut h);
        key.hash(&mut h);
        let mut rows: Vec<(u128, i128)> = t.iter_keys().collect();
        rows.sort_unstable();
        for (k, c) in rows {
            k.hash(&mut h);
            c.hash(&mut h);
        }
    }
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::fixtures::university_schema;

    #[test]
    fn hit_miss_accounting() {
        let s = university_schema();
        let v = RVar::EntityAttr { et: 0, attr: 0 };
        let mut c = CtCache::new();
        let key = CtCache::key(&[v], &[0]);
        assert!(c.get(&key).is_none());
        assert_eq!(c.misses, 1);

        let mut t = CtTable::new(&s, vec![v]).unwrap();
        t.add(&[1], 3).unwrap();
        let bytes = t.bytes();
        c.insert(key.clone(), t);
        assert!(c.get(&key).is_some());
        assert_eq!(c.hits, 1);
        assert_eq!(c.bytes(), bytes);
        assert_eq!(c.rows_inserted, 1);
        assert!(c.mem.peak_bytes >= bytes);

        c.clear();
        assert_eq!(c.bytes(), 0);
        assert!(c.mem.peak_bytes >= bytes); // peak survives clears
    }

    #[test]
    fn delta_application_keeps_bytes_exact() {
        let s = university_schema();
        let v = RVar::EntityAttr { et: 0, attr: 0 };
        let mut c = CtCache::new();
        let key = CtCache::key(&[v], &[0]);
        let mut t = CtTable::new(&s, vec![v]).unwrap();
        t.add(&[0], 3).unwrap();
        t.add(&[1], 2).unwrap();
        c.insert(key.clone(), t);

        let mut d = CtTable::new(&s, vec![v]).unwrap();
        d.add(&[0], -3).unwrap(); // row compacts away
        d.add(&[2], 7).unwrap();
        c.apply_delta(&key, &d).unwrap();
        assert_eq!(c.cells_deltaed, 2);
        let cur = c.peek(&key).unwrap();
        assert_eq!(cur.get(&[0]).unwrap(), 0);
        assert_eq!(cur.get(&[2]).unwrap(), 7);
        assert_eq!(c.bytes(), c.peek(&key).unwrap().bytes());

        // absent key errors; remove subtracts bytes
        let ghost = CtCache::key(&[v], &[1]);
        assert!(c.apply_delta(&ghost, &d).is_err());
        assert!(c.remove(&key).is_some());
        assert_eq!(c.bytes(), 0);
        assert!(c.remove(&key).is_none());
    }

    #[test]
    fn digest_ignores_insertion_order_but_not_tags() {
        let s = university_schema();
        let v = RVar::EntityAttr { et: 0, attr: 0 };
        let w = RVar::EntityAttr { et: 1, attr: 0 };
        let mk = |pairs: &[(RVar, u32, i128)]| {
            let mut c = CtCache::new();
            for &(var, val, n) in pairs {
                let mut t = CtTable::new(&s, vec![var]).unwrap();
                t.add(&[val], n).unwrap();
                c.insert(CtCache::key(&[var], &[0]), t);
            }
            c
        };
        let a = mk(&[(v, 1, 3), (w, 0, 2)]);
        let b = mk(&[(w, 0, 2), (v, 1, 3)]);
        assert_eq!(digest_caches(&[(0, &a)]), digest_caches(&[(0, &b)]));
        // the tag distinguishes positive from complete caches
        assert_ne!(digest_caches(&[(0, &a)]), digest_caches(&[(1, &a)]));
        // and content changes change the digest
        let c = mk(&[(v, 1, 4), (w, 0, 2)]);
        assert_ne!(digest_caches(&[(0, &a)]), digest_caches(&[(0, &c)]));
    }
}
