//! Shared strategy machinery: the timed metadata context, the
//! lattice-cache [`ChainSource`] (projection instead of JOINs), and the
//! timing wrapper that attributes positive-vs-negative work inside a
//! Möbius Join.

use std::time::{Duration, Instant};

use crate::ct::cttable::CtTable;
use crate::ct::mobius::ChainSource;
use crate::ct::project::project;
use crate::db::catalog::Database;
use crate::db::query::{groupby_entity, positive_chain_ct, JoinStats};
use crate::db::schema::Schema;
use crate::error::{Error, Result};
use crate::lattice::Lattice;
use crate::meta::extract::{plan_chain, vars_for_entity, Metadata, QueryPlan};
use crate::meta::rvar::RVar;
use crate::metrics::timing::{Deadline, Phase, PhaseTimer};
use crate::strategies::cache::{CtCache, CacheKey};

/// Metadata + lattice + query plans, built during the MetaData phase.
#[derive(Clone)]
pub struct LatticeCtx {
    pub metadata: Metadata,
    pub lattice: Lattice,
    pub plans: Vec<QueryPlan>,
}

impl LatticeCtx {
    /// Build, attributing wall time to the MetaData phase.
    pub fn build(
        db: &Database,
        max_chain_length: usize,
        timer: &mut PhaseTimer,
    ) -> Result<Self> {
        timer.time(Phase::Metadata, || {
            let metadata = Metadata::extract(db);
            let lattice = Lattice::build(&db.schema, max_chain_length)?;
            let mut plans = Vec::with_capacity(lattice.len());
            for p in &lattice.points {
                plans.push(plan_chain(db, &p.rels)?);
            }
            Ok(LatticeCtx { metadata, lattice, plans })
        })
    }
}

/// Key for a lattice point's positive ct-table in a [`CtCache`].
///
/// The key must identify the *chain*, not just the variable list: two
/// chains can share `(attr_vars, pops)` when a relationship has no
/// attributes (e.g. hepatitis' `{Took, ExamBio}` vs `{Took, BioOf,
/// ExamBio}` — `BioOf` is attribute-less), so the indicator variables of
/// the chain's rels are prepended to disambiguate.
pub fn lp_key(rels: &[usize], attr_vars: &[RVar], pops: &[usize]) -> CacheKey {
    let mut vars: Vec<RVar> = rels.iter().map(|&rel| RVar::RelInd { rel }).collect();
    vars.extend(attr_vars.iter().copied());
    CtCache::key(&vars, pops)
}

/// Key for an entity type's full marginal.
pub fn entity_key(et: usize) -> CacheKey {
    (Vec::new(), vec![et])
}

/// One independent unit of positive pre-count work.
///
/// The pre-counting positive phase decomposes into embarrassingly
/// parallel tasks — one GROUP BY per entity type, one chain JOIN per
/// lattice point.  Each task reads only the (shared, immutable) database
/// and writes one ct-table, so shards can execute disjoint task subsets
/// with no coordination; the coordinator merges the resulting
/// `(key, table)` pairs in task order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PositiveTask {
    /// Full marginal of one entity type (GROUP BY, no JOINs).
    Entity(usize),
    /// Positive ct-table of one lattice point (INNER JOIN GROUP BY).
    Point(usize),
}

/// The full positive-phase task list, in the canonical (deterministic)
/// order: entity marginals first, then lattice points by ascending id.
pub fn positive_tasks(db: &Database, ctx: &LatticeCtx) -> Vec<PositiveTask> {
    let mut tasks: Vec<PositiveTask> =
        (0..db.schema.entities.len()).map(PositiveTask::Entity).collect();
    tasks.extend((0..ctx.lattice.points.len()).map(PositiveTask::Point));
    tasks
}

/// Execute one positive task, returning the cache key and table it
/// produces.  `stats` receives the task's query counters.
pub fn run_positive_task(
    db: &Database,
    ctx: &LatticeCtx,
    task: PositiveTask,
    stats: &mut JoinStats,
) -> Result<(CacheKey, CtTable)> {
    match task {
        PositiveTask::Entity(et) => {
            let vars = vars_for_entity(&db.schema, et);
            stats.entity_queries += 1;
            Ok((entity_key(et), groupby_entity(db, et, &vars)?))
        }
        PositiveTask::Point(id) => {
            let p = &ctx.lattice.points[id];
            let t = positive_chain_ct(db, &p.rels, &p.attr_vars, stats)?;
            Ok((lp_key(&p.rels, &p.attr_vars, &p.pops), t))
        }
    }
}

/// Fill `cache` with the positive ct-table of every lattice point and the
/// full marginal of every entity type — the pre-counting positive phase
/// shared by PRECOUNT and HYBRID (Algorithms 1 & 3, lines 1-3).
pub fn fill_positive_cache(
    db: &Database,
    ctx: &LatticeCtx,
    cache: &mut CtCache,
    timer: &mut PhaseTimer,
    deadline: &Deadline,
    stats: &mut JoinStats,
) -> Result<()> {
    for task in positive_tasks(db, ctx) {
        deadline.check(match task {
            PositiveTask::Entity(_) => "positive ct (entity)",
            PositiveTask::Point(_) => "positive ct (lattice)",
        })?;
        let (key, t) = timer.time(Phase::Positive, || {
            run_positive_task(db, ctx, task, stats)
        })?;
        cache.insert(key, t);
    }
    Ok(())
}

/// A [`ChainSource`] that serves positive counts by *projection from the
/// lattice cache* — no table JOINs (the pre-counting trick PRECOUNT and
/// HYBRID share).
pub struct LatticeCacheSource<'a> {
    pub db: &'a Database,
    pub lattice: &'a Lattice,
    pub cache: &'a mut CtCache,
}

impl ChainSource for LatticeCacheSource<'_> {
    fn positive_chain_ct(&mut self, chain: &[usize], vars: &[RVar]) -> Result<CtTable> {
        let p = self.lattice.point(chain).ok_or_else(|| {
            Error::Strategy(format!(
                "chain {chain:?} exceeds the lattice (max length {}); \
                 ONDEMAND must be used",
                self.lattice.max_length
            ))
        })?;
        let key = lp_key(&p.rels, &p.attr_vars, &p.pops);
        let full = self
            .cache
            .get(&key)
            .ok_or_else(|| Error::Strategy(format!("positive ct missing for {chain:?}")))?;
        project(full, vars)
    }

    fn entity_marginal(&mut self, et: usize, vars: &[RVar]) -> Result<CtTable> {
        let key = entity_key(et);
        let full = self
            .cache
            .get(&key)
            .ok_or_else(|| Error::Strategy(format!("entity marginal missing for {et}")))?;
        project(full, vars)
    }

    fn schema(&self) -> &Schema {
        &self.db.schema
    }

    fn population(&self, et: usize) -> i128 {
        self.db.population(et) as i128
    }
}

/// A read-only [`ChainSource`] over a *shared* lattice cache.
///
/// [`LatticeCacheSource`] needs `&mut CtCache` because lookups maintain
/// hit/miss counters.  Worker shards of the parallel coordinator instead
/// read the positive cache concurrently through an immutable borrow
/// ([`CtCache::peek`]), which makes the source `Send`-able into scoped
/// threads: the cache is frozen after the positive phase, so shared reads
/// are race-free by construction.  Hit accounting, when wanted, is the
/// coordinator's job.
pub struct SharedLatticeSource<'a> {
    pub db: &'a Database,
    pub lattice: &'a Lattice,
    pub cache: &'a CtCache,
}

impl ChainSource for SharedLatticeSource<'_> {
    fn positive_chain_ct(&mut self, chain: &[usize], vars: &[RVar]) -> Result<CtTable> {
        let p = self.lattice.point(chain).ok_or_else(|| {
            Error::Strategy(format!(
                "chain {chain:?} exceeds the lattice (max length {}); \
                 ONDEMAND must be used",
                self.lattice.max_length
            ))
        })?;
        let key = lp_key(&p.rels, &p.attr_vars, &p.pops);
        let full = self
            .cache
            .peek(&key)
            .ok_or_else(|| Error::Strategy(format!("positive ct missing for {chain:?}")))?;
        project(full, vars)
    }

    fn entity_marginal(&mut self, et: usize, vars: &[RVar]) -> Result<CtTable> {
        let full = self
            .cache
            .peek(&entity_key(et))
            .ok_or_else(|| Error::Strategy(format!("entity marginal missing for {et}")))?;
        project(full, vars)
    }

    fn schema(&self) -> &Schema {
        &self.db.schema
    }

    fn population(&self, et: usize) -> i128 {
        self.db.population(et) as i128
    }
}

/// Re-base a ct-table counted over a lattice point's populations
/// `point_pops` onto the requested context `ctx_pops`: divide out the
/// point's extra populations (every count is a multiple of their product)
/// and multiply in the context's missing ones.  Extracted from PRECOUNT's
/// serve path so the parallel coordinator shares the exact arithmetic.
pub fn narrow_to_ctx(
    db: &Database,
    ct: &mut CtTable,
    point_pops: &[usize],
    ctx_pops: &[usize],
    vars: &[RVar],
) -> Result<()> {
    let extra: i128 = point_pops
        .iter()
        .filter(|e| !ctx_pops.contains(e))
        .map(|&e| db.population(e) as i128)
        .product();
    let missing: i128 = ctx_pops
        .iter()
        .filter(|e| !point_pops.contains(e))
        .map(|&e| db.population(e) as i128)
        .product();
    ct.divide_exact(extra).map_err(|e| {
        Error::Strategy(format!(
            "context narrowing failed for family {vars:?} ctx {ctx_pops:?} \
             (point pops {point_pops:?}): {e}"
        ))
    })?;
    ct.scale(missing)
}

/// Wraps a [`ChainSource`], accumulating the wall time spent inside its
/// positive-count calls, so a Möbius Join's total time can be split into
/// positive (data access / projection) and negative (inclusion-exclusion)
/// components.
pub struct TimedSource<'s> {
    pub inner: &'s mut dyn ChainSource,
    pub positive_elapsed: Duration,
}

impl<'s> TimedSource<'s> {
    pub fn new(inner: &'s mut dyn ChainSource) -> Self {
        TimedSource { inner, positive_elapsed: Duration::ZERO }
    }
}

impl ChainSource for TimedSource<'_> {
    fn positive_chain_ct(&mut self, chain: &[usize], vars: &[RVar]) -> Result<CtTable> {
        let t0 = Instant::now();
        let r = self.inner.positive_chain_ct(chain, vars);
        self.positive_elapsed += t0.elapsed();
        r
    }

    fn entity_marginal(&mut self, et: usize, vars: &[RVar]) -> Result<CtTable> {
        let t0 = Instant::now();
        let r = self.inner.entity_marginal(et, vars);
        self.positive_elapsed += t0.elapsed();
        r
    }

    fn schema(&self) -> &Schema {
        self.inner.schema()
    }

    fn population(&self, et: usize) -> i128 {
        self.inner.population(et)
    }
}

/// Union of the populations referenced by `vars`.
pub fn var_pops(schema: &Schema, vars: &[RVar]) -> Vec<usize> {
    let mut pops: Vec<usize> =
        vars.iter().flat_map(|v| v.populations(schema)).collect();
    pops.sort_unstable();
    pops.dedup();
    pops
}

/// Relationships referenced by `vars`.
pub fn var_rels(vars: &[RVar]) -> Vec<usize> {
    let mut rels: Vec<usize> = vars.iter().filter_map(|v| v.rel()).collect();
    rels.sort_unstable();
    rels.dedup();
    rels
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ct::mobius::{brute_force_complete, mobius_complete};
    use crate::db::fixtures::university_db;

    #[test]
    fn lattice_cache_source_matches_direct() {
        let db = university_db();
        let mut timer = PhaseTimer::default();
        let ctx = LatticeCtx::build(&db, 3, &mut timer).unwrap();
        assert!(timer.metadata > Duration::ZERO);

        let mut cache = CtCache::new();
        let mut stats = JoinStats::default();
        fill_positive_cache(
            &db,
            &ctx,
            &mut cache,
            &mut timer,
            &Deadline::unlimited(),
            &mut stats,
        )
        .unwrap();
        // 3 entity marginals + 3 lattice points
        assert_eq!(cache.len(), 6);
        assert_eq!(stats.chain_queries, 3);

        let vars = vec![
            RVar::RelInd { rel: 0 },
            RVar::RelAttr { rel: 0, attr: 1 },
            RVar::EntityAttr { et: 1, attr: 0 },
        ];
        let mut src =
            LatticeCacheSource { db: &db, lattice: &ctx.lattice, cache: &mut cache };
        let fast = mobius_complete(&mut src, &vars, &[0, 1]).unwrap();
        let brute = brute_force_complete(&db, &vars, &[0, 1]).unwrap();
        assert_eq!(fast.n_rows(), brute.n_rows());
        for (v, c) in brute.iter_rows() {
            assert_eq!(fast.get(&v).unwrap(), c);
        }
    }

    #[test]
    fn chain_beyond_lattice_errors() {
        let db = university_db();
        let mut timer = PhaseTimer::default();
        let ctx = LatticeCtx::build(&db, 1, &mut timer).unwrap();
        let mut cache = CtCache::new();
        let mut stats = JoinStats::default();
        fill_positive_cache(
            &db,
            &ctx,
            &mut cache,
            &mut timer,
            &Deadline::unlimited(),
            &mut stats,
        )
        .unwrap();
        let mut src =
            LatticeCacheSource { db: &db, lattice: &ctx.lattice, cache: &mut cache };
        let e = src.positive_chain_ct(&[0, 1], &[]).unwrap_err();
        assert!(matches!(e, Error::Strategy(_)));
    }
}
