//! HYBRID (Algorithm 3) — the paper's contribution.
//!
//! Pre-counting for the JOIN problem: like PRECOUNT, one positive
//! ct-table per lattice point is built before search, so scoring never
//! JOINs (positives come from projections, Alg. 3 line 5).
//!
//! Post-counting for the negation problem: like ONDEMAND, the Möbius
//! Join runs per *family* (Alg. 3 line 6), so the huge complete lattice
//! tables of PRECOUNT are never materialized.  Assuming small families,
//! this is the sweet spot that scales to millions of facts.

use crate::ct::cttable::CtTable;
use crate::ct::mobius::mobius_complete;
use crate::db::catalog::Database;
use crate::db::query::JoinStats;
use crate::error::Result;
use crate::meta::rvar::RVar;
use crate::metrics::memory::MemTracker;
use crate::metrics::timing::{Deadline, Phase, PhaseTimer};
use crate::strategies::cache::{digest_caches, CtCache};
use crate::strategies::common::{
    fill_positive_cache, LatticeCacheSource, LatticeCtx, TimedSource,
};
use crate::strategies::traits::{CountingStrategy, StrategyConfig, StrategyReport};

/// The HYBRID strategy.
pub struct Hybrid<'a> {
    db: &'a Database,
    cfg: StrategyConfig,
    ctx: LatticeCtx,
    /// Positive lattice ct-tables + entity marginals (the pre-count).
    positive: CtCache,
    /// Post-counting cache of family ct-tables.
    family_cache: CtCache,
    timer: PhaseTimer,
    deadline: Deadline,
    join_stats: JoinStats,
    mem: MemTracker,
    families_served: u64,
    rows_generated: u64,
    prepared: bool,
}

impl<'a> Hybrid<'a> {
    pub fn new(db: &'a Database, cfg: StrategyConfig) -> Result<Self> {
        let deadline = Deadline::new(cfg.budget);
        let mut timer = PhaseTimer::default();
        let ctx = LatticeCtx::build(db, cfg.max_chain_length, &mut timer)?;
        Ok(Hybrid {
            db,
            cfg,
            ctx,
            positive: CtCache::new(),
            family_cache: CtCache::new(),
            timer,
            deadline,
            join_stats: JoinStats::default(),
            mem: MemTracker::default(),
            families_served: 0,
            rows_generated: 0,
            prepared: false,
        })
    }
}

impl CountingStrategy for Hybrid<'_> {
    fn name(&self) -> &'static str {
        "HYBRID"
    }

    /// Positive phase only (Alg. 3 lines 1-3): JOIN once per lattice
    /// point, **no** Möbius here.
    fn prepare(&mut self) -> Result<()> {
        if self.prepared {
            return Ok(());
        }
        fill_positive_cache(
            self.db,
            &self.ctx,
            &mut self.positive,
            &mut self.timer,
            &self.deadline,
            &mut self.join_stats,
        )?;
        self.prepared = true;
        Ok(())
    }

    fn ct_for_family(&mut self, vars: &[RVar], ctx_pops: &[usize]) -> Result<CtTable> {
        if !self.prepared {
            self.prepare()?;
        }
        self.deadline.check("family count (hybrid)")?;
        self.families_served += 1;
        let key = CtCache::key(vars, ctx_pops);
        if self.cfg.family_cache {
            if let Some(hit) = self.family_cache.get(&key) {
                return Ok(hit.clone());
            }
        }
        // Projection for positives (Alg. 3 line 5) + family Möbius
        // (line 6).  TimedSource splits the two components.
        let t0 = std::time::Instant::now();
        let mut lattice_src = LatticeCacheSource {
            db: self.db,
            lattice: &self.ctx.lattice,
            cache: &mut self.positive,
        };
        let ct = {
            let mut timed = TimedSource::new(&mut lattice_src);
            let ct = mobius_complete(&mut timed, vars, ctx_pops)?;
            self.timer.add(Phase::Positive, timed.positive_elapsed);
            self.timer
                .add(Phase::Negative, t0.elapsed().saturating_sub(timed.positive_elapsed));
            ct
        };
        self.rows_generated += ct.n_rows() as u64;
        self.mem.observe_transient(ct.bytes());
        if self.cfg.family_cache {
            self.family_cache.insert(key, ct.clone());
        }
        Ok(ct)
    }

    fn report(&self) -> StrategyReport {
        let mut peak = self.mem;
        peak.merge_peak(&self.positive.mem);
        peak.peak_bytes = peak
            .peak_bytes
            .max(self.positive.mem.current_bytes + self.family_cache.mem.peak_bytes);
        StrategyReport {
            name: self.name().into(),
            timing: self.timer,
            join_stats: self.join_stats,
            cache_bytes: self.positive.bytes() + self.family_cache.bytes(),
            peak_ct_bytes: peak.peak_bytes,
            ct_rows_generated: self.rows_generated,
            families_served: self.families_served,
            cache_hits: self.family_cache.hits,
            cache_misses: self.family_cache.misses,
            ..Default::default()
        }
    }

    fn cache_digest(&self) -> u64 {
        digest_caches(&[(0, &self.positive), (2, &self.family_cache)])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ct::mobius::brute_force_complete;
    use crate::db::fixtures::university_db;

    fn family() -> Vec<RVar> {
        vec![
            RVar::RelInd { rel: 0 },
            RVar::RelAttr { rel: 0, attr: 1 },
            RVar::EntityAttr { et: 1, attr: 0 },
        ]
    }

    #[test]
    fn counts_match_brute_force() {
        let db = university_db();
        let mut s = Hybrid::new(&db, StrategyConfig::default()).unwrap();
        s.prepare().unwrap();
        let ct = s.ct_for_family(&family(), &[0, 1]).unwrap();
        let brute = brute_force_complete(&db, &family(), &[0, 1]).unwrap();
        assert_eq!(ct.n_rows(), brute.n_rows());
        for (v, c) in brute.iter_rows() {
            assert_eq!(ct.get(&v).unwrap(), c);
        }
    }

    #[test]
    fn no_joins_during_search() {
        let db = university_db();
        let mut s = Hybrid::new(&db, StrategyConfig::default()).unwrap();
        s.prepare().unwrap();
        let joins_after_prepare = s.join_stats.chain_queries;
        s.ct_for_family(&family(), &[0, 1]).unwrap();
        let vars2 = vec![RVar::RelInd { rel: 1 }, RVar::EntityAttr { et: 2, attr: 0 }];
        s.ct_for_family(&vars2, &[1, 2]).unwrap();
        // the pre-count is the only JOIN work — that's the whole point
        assert_eq!(s.join_stats.chain_queries, joins_after_prepare);
    }

    #[test]
    fn cross_lattice_family() {
        // family spanning both relationships
        let db = university_db();
        let mut s = Hybrid::new(&db, StrategyConfig::default()).unwrap();
        let vars = vec![
            RVar::RelInd { rel: 0 },
            RVar::RelInd { rel: 1 },
            RVar::RelAttr { rel: 1, attr: 0 },
        ];
        let ct = s.ct_for_family(&vars, &[0, 1, 2]).unwrap();
        let brute = brute_force_complete(&db, &vars, &[0, 1, 2]).unwrap();
        for (v, c) in brute.iter_rows() {
            assert_eq!(ct.get(&v).unwrap(), c);
        }
    }

    #[test]
    fn family_cache_hits() {
        let db = university_db();
        let mut s = Hybrid::new(&db, StrategyConfig::default()).unwrap();
        s.ct_for_family(&family(), &[0, 1]).unwrap();
        s.ct_for_family(&family(), &[0, 1]).unwrap();
        assert_eq!(s.report().cache_hits, 1);
    }

    #[test]
    fn timing_components_populated() {
        let db = university_db();
        let mut s = Hybrid::new(&db, StrategyConfig::default()).unwrap();
        s.prepare().unwrap();
        s.ct_for_family(&family(), &[0, 1]).unwrap();
        let t = s.report().timing;
        assert!(t.metadata > std::time::Duration::ZERO);
        assert!(t.positive > std::time::Duration::ZERO);
        assert!(t.negative > std::time::Duration::ZERO);
    }
}
