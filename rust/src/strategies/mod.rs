//! The paper's contribution: three count-caching strategies for serving
//! complete ct-tables to the model search (paper Table 2):
//!
//! | strategy   | positive ct input | negative ct input | algorithm |
//! |------------|-------------------|-------------------|-----------|
//! | [`precount::Precount`] | lattice point | lattice point | Alg. 1 |
//! | [`ondemand::OnDemand`] | family        | family        | Alg. 2 |
//! | [`hybrid::Hybrid`]     | lattice point | family        | Alg. 3 |
//!
//! (The fourth cell of Table 2 — negative ct per lattice point with
//! positive ct per family — is labelled IMPOSSIBLE by the paper: the
//! Möbius Join cannot produce a wider table than its positive input.)
//!
//! All three implement [`traits::CountingStrategy`] and are verified to
//! produce **identical** family ct-tables (see
//! `rust/tests/strategy_equivalence.rs`).

pub mod cache;
pub mod common;
pub mod hybrid;
pub mod ondemand;
pub mod precount;
pub mod traits;

pub use hybrid::Hybrid;
pub use ondemand::OnDemand;
pub use precount::Precount;
pub use traits::{CountingStrategy, StrategyConfig, StrategyReport};

use crate::db::catalog::Database;
use crate::error::Result;

/// Strategy selector for CLIs and benches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StrategyKind {
    Precount,
    OnDemand,
    Hybrid,
}

impl StrategyKind {
    pub const ALL: [StrategyKind; 3] =
        [StrategyKind::Precount, StrategyKind::OnDemand, StrategyKind::Hybrid];

    pub fn name(&self) -> &'static str {
        match self {
            StrategyKind::Precount => "PRECOUNT",
            StrategyKind::OnDemand => "ONDEMAND",
            StrategyKind::Hybrid => "HYBRID",
        }
    }

    pub fn parse(s: &str) -> Option<StrategyKind> {
        match s.to_ascii_lowercase().as_str() {
            "precount" | "pre" | "p" => Some(StrategyKind::Precount),
            "ondemand" | "post" | "o" => Some(StrategyKind::OnDemand),
            "hybrid" | "h" => Some(StrategyKind::Hybrid),
            _ => None,
        }
    }

    /// Instantiate (metadata phase runs inside).
    pub fn build<'a>(
        &self,
        db: &'a Database,
        cfg: StrategyConfig,
    ) -> Result<Box<dyn CountingStrategy + 'a>> {
        Ok(match self {
            StrategyKind::Precount => Box::new(Precount::new(db, cfg)?),
            StrategyKind::OnDemand => Box::new(OnDemand::new(db, cfg)?),
            StrategyKind::Hybrid => Box::new(Hybrid::new(db, cfg)?),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parsing() {
        assert_eq!(StrategyKind::parse("hybrid"), Some(StrategyKind::Hybrid));
        assert_eq!(StrategyKind::parse("PRE"), Some(StrategyKind::Precount));
        assert_eq!(StrategyKind::parse("post"), Some(StrategyKind::OnDemand));
        assert_eq!(StrategyKind::parse("nope"), None);
        for k in StrategyKind::ALL {
            assert!(!k.name().is_empty());
        }
    }
}
