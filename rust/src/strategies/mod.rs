//! The paper's contribution: three count-caching strategies for serving
//! complete ct-tables to the model search (paper Table 2):
//!
//! | strategy   | positive ct input | negative ct input | algorithm |
//! |------------|-------------------|-------------------|-----------|
//! | [`precount::Precount`] | lattice point | lattice point | Alg. 1 |
//! | [`ondemand::OnDemand`] | family        | family        | Alg. 2 |
//! | [`hybrid::Hybrid`]     | lattice point | family        | Alg. 3 |
//!
//! (The fourth cell of Table 2 — negative ct per lattice point with
//! positive ct per family — is labelled IMPOSSIBLE by the paper: the
//! Möbius Join cannot produce a wider table than its positive input.)
//!
//! A fourth strategy, [`adaptive::Adaptive`], generalizes the table into
//! a *planner*: per lattice point it chooses pre or post counting from
//! sampling-based cost estimates under an explicit memory budget
//! ([`traits::StrategyConfig::mem_budget`]), spanning the whole
//! ONDEMAND → HYBRID → PRECOUNT spectrum.
//!
//! All strategies implement [`traits::CountingStrategy`] and are
//! verified to produce **identical** family ct-tables (see
//! `rust/tests/strategy_equivalence.rs`).

pub mod adaptive;
pub mod cache;
pub mod common;
pub mod hybrid;
pub mod ondemand;
pub mod precount;
pub mod traits;

pub use adaptive::Adaptive;
pub use hybrid::Hybrid;
pub use ondemand::OnDemand;
pub use precount::Precount;
pub use traits::{CountingStrategy, StrategyConfig, StrategyReport};

use crate::db::catalog::Database;
use crate::error::Result;

/// Strategy selector for CLIs and benches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StrategyKind {
    Precount,
    OnDemand,
    Hybrid,
    /// The planner-driven strategy; honors
    /// [`StrategyConfig::mem_budget`] and
    /// [`StrategyConfig::estimator`].
    Adaptive,
}

impl StrategyKind {
    /// The paper's three fixed strategies (Table 2) — the grid every
    /// figure/table experiment sweeps.
    pub const ALL: [StrategyKind; 3] =
        [StrategyKind::Precount, StrategyKind::OnDemand, StrategyKind::Hybrid];

    /// All strategies including the ADAPTIVE planner.
    pub const ALL_WITH_ADAPTIVE: [StrategyKind; 4] = [
        StrategyKind::Precount,
        StrategyKind::OnDemand,
        StrategyKind::Hybrid,
        StrategyKind::Adaptive,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            StrategyKind::Precount => "PRECOUNT",
            StrategyKind::OnDemand => "ONDEMAND",
            StrategyKind::Hybrid => "HYBRID",
            StrategyKind::Adaptive => "ADAPTIVE",
        }
    }

    pub fn parse(s: &str) -> Option<StrategyKind> {
        match s.to_ascii_lowercase().as_str() {
            "precount" | "pre" | "p" => Some(StrategyKind::Precount),
            "ondemand" | "post" | "o" => Some(StrategyKind::OnDemand),
            "hybrid" | "h" => Some(StrategyKind::Hybrid),
            "adaptive" | "a" => Some(StrategyKind::Adaptive),
            _ => None,
        }
    }

    /// Instantiate (metadata phase runs inside).
    pub fn build<'a>(
        &self,
        db: &'a Database,
        cfg: StrategyConfig,
    ) -> Result<Box<dyn CountingStrategy + 'a>> {
        Ok(match self {
            StrategyKind::Precount => Box::new(Precount::new(db, cfg)?),
            StrategyKind::OnDemand => Box::new(OnDemand::new(db, cfg)?),
            StrategyKind::Hybrid => Box::new(Hybrid::new(db, cfg)?),
            StrategyKind::Adaptive => Box::new(Adaptive::new(db, cfg)?),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parsing() {
        assert_eq!(StrategyKind::parse("hybrid"), Some(StrategyKind::Hybrid));
        assert_eq!(StrategyKind::parse("PRE"), Some(StrategyKind::Precount));
        assert_eq!(StrategyKind::parse("post"), Some(StrategyKind::OnDemand));
        assert_eq!(StrategyKind::parse("adaptive"), Some(StrategyKind::Adaptive));
        assert_eq!(StrategyKind::parse("nope"), None);
        for k in StrategyKind::ALL_WITH_ADAPTIVE {
            assert!(!k.name().is_empty());
        }
        assert!(!StrategyKind::ALL.contains(&StrategyKind::Adaptive));
    }
}
