//! ONDEMAND (Algorithm 2): post-counting.  No preparation; each family
//! scored during search triggers fresh INNER JOINs for its positive
//! counts, followed by a family-local Möbius Join.  Results are cached in
//! case the search revisits the pattern.
//!
//! The strength: only patterns the search actually generates are counted,
//! and family tables are small (Equation 4).  The weakness (the paper's
//! JOIN problem): every cache miss pays a full data access — on the large
//! databases (IMDb, Visual Genome) this blows the time budget.

use crate::ct::cttable::CtTable;
use crate::ct::mobius::mobius_complete;
use crate::db::catalog::Database;
use crate::db::query::{DirectSource, JoinStats};
use crate::error::Result;
use crate::meta::rvar::RVar;
use crate::metrics::memory::MemTracker;
use crate::metrics::timing::{Deadline, Phase, PhaseTimer};
use crate::strategies::cache::{digest_caches, CtCache};
use crate::strategies::common::{LatticeCtx, TimedSource};
use crate::strategies::traits::{CountingStrategy, StrategyConfig, StrategyReport};

/// The ONDEMAND strategy.
pub struct OnDemand<'a> {
    db: &'a Database,
    cfg: StrategyConfig,
    /// Metadata is still extracted (the search needs the lattice); this
    /// is why ONDEMAND inherits the MetaData overhead in Figure 3.
    #[allow(dead_code)]
    ctx: LatticeCtx,
    /// Post-counting cache of family ct-tables.
    family_cache: CtCache,
    timer: PhaseTimer,
    deadline: Deadline,
    join_stats: JoinStats,
    mem: MemTracker,
    families_served: u64,
    rows_generated: u64,
}

impl<'a> OnDemand<'a> {
    pub fn new(db: &'a Database, cfg: StrategyConfig) -> Result<Self> {
        let deadline = Deadline::new(cfg.budget);
        let mut timer = PhaseTimer::default();
        let ctx = LatticeCtx::build(db, cfg.max_chain_length, &mut timer)?;
        Ok(OnDemand {
            db,
            cfg,
            ctx,
            family_cache: CtCache::new(),
            timer,
            deadline,
            join_stats: JoinStats::default(),
            mem: MemTracker::default(),
            families_served: 0,
            rows_generated: 0,
        })
    }
}

impl CountingStrategy for OnDemand<'_> {
    fn name(&self) -> &'static str {
        "ONDEMAND"
    }

    /// Post-counting does no preparation (Algorithm 2 has no pre-phase).
    fn prepare(&mut self) -> Result<()> {
        Ok(())
    }

    fn ct_for_family(&mut self, vars: &[RVar], ctx_pops: &[usize]) -> Result<CtTable> {
        self.deadline.check("family count (ondemand)")?;
        self.families_served += 1;
        let key = CtCache::key(vars, ctx_pops);
        if self.cfg.family_cache {
            if let Some(hit) = self.family_cache.get(&key) {
                return Ok(hit.clone());
            }
        }
        // Fresh joins (Alg. 2 line 2) + family Möbius (line 3).
        let t0 = std::time::Instant::now();
        let mut direct = DirectSource::new(self.db);
        let ct = {
            let mut timed = TimedSource::new(&mut direct);
            let ct = mobius_complete(&mut timed, vars, ctx_pops)?;
            self.timer.add(Phase::Positive, timed.positive_elapsed);
            self.timer
                .add(Phase::Negative, t0.elapsed().saturating_sub(timed.positive_elapsed));
            ct
        };
        self.join_stats.merge(&direct.stats);
        self.rows_generated += ct.n_rows() as u64;
        self.mem.observe_transient(ct.bytes());
        if self.cfg.family_cache {
            self.family_cache.insert(key, ct.clone());
        }
        Ok(ct)
    }

    fn report(&self) -> StrategyReport {
        let mut peak = self.mem;
        peak.merge_peak(&self.family_cache.mem);
        peak.peak_bytes = peak
            .peak_bytes
            .max(self.family_cache.mem.current_bytes);
        StrategyReport {
            name: self.name().into(),
            timing: self.timer,
            join_stats: self.join_stats,
            cache_bytes: self.family_cache.bytes(),
            peak_ct_bytes: peak.peak_bytes,
            ct_rows_generated: self.rows_generated,
            families_served: self.families_served,
            cache_hits: self.family_cache.hits,
            cache_misses: self.family_cache.misses,
            ..Default::default()
        }
    }

    fn cache_digest(&self) -> u64 {
        digest_caches(&[(2, &self.family_cache)])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ct::mobius::brute_force_complete;
    use crate::db::fixtures::university_db;

    fn family() -> Vec<RVar> {
        vec![
            RVar::RelInd { rel: 0 },
            RVar::RelAttr { rel: 0, attr: 0 },
            RVar::EntityAttr { et: 0, attr: 0 },
        ]
    }

    #[test]
    fn counts_match_brute_force() {
        let db = university_db();
        let mut s = OnDemand::new(&db, StrategyConfig::default()).unwrap();
        s.prepare().unwrap();
        let ct = s.ct_for_family(&family(), &[0, 1]).unwrap();
        let brute = brute_force_complete(&db, &family(), &[0, 1]).unwrap();
        assert_eq!(ct.n_rows(), brute.n_rows());
        for (v, c) in brute.iter_rows() {
            assert_eq!(ct.get(&v).unwrap(), c);
        }
    }

    #[test]
    fn revisits_hit_the_cache() {
        let db = university_db();
        let mut s = OnDemand::new(&db, StrategyConfig::default()).unwrap();
        let a = s.ct_for_family(&family(), &[0, 1]).unwrap();
        let joins_after_first = s.join_stats.chain_queries;
        let b = s.ct_for_family(&family(), &[0, 1]).unwrap();
        assert_eq!(s.join_stats.chain_queries, joins_after_first); // no new joins
        assert_eq!(s.report().cache_hits, 1);
        assert_eq!(a.n_rows(), b.n_rows());
    }

    #[test]
    fn no_family_cache_config() {
        let db = university_db();
        let cfg = StrategyConfig { family_cache: false, ..Default::default() };
        let mut s = OnDemand::new(&db, cfg).unwrap();
        s.ct_for_family(&family(), &[0, 1]).unwrap();
        let j1 = s.join_stats.chain_queries;
        s.ct_for_family(&family(), &[0, 1]).unwrap();
        assert!(s.join_stats.chain_queries > j1); // re-joined
    }

    #[test]
    fn executes_many_joins_per_family() {
        // the JOIN problem: a 2-rel family costs joins for every subset
        let db = university_db();
        let mut s = OnDemand::new(&db, StrategyConfig::default()).unwrap();
        let vars = vec![RVar::RelInd { rel: 0 }, RVar::RelInd { rel: 1 }];
        s.ct_for_family(&vars, &[0, 1, 2]).unwrap();
        // subsets {0}, {1}, {0,1} each need chain queries
        assert!(s.join_stats.chain_queries >= 3);
    }
}
