//! PRECOUNT (Algorithm 1): pre-compute a *complete* ct-table for every
//! lattice point before model search; serve families by projection only.
//!
//! The strength: one JOIN pass over the data, no counting work during
//! search.  The weakness (the paper's negation problem at scale): the
//! complete lattice tables include every negative-relationship
//! configuration, so they can dwarf the data itself (Table 5's
//! ct(database) column, Equation 3 growth).

use crate::ct::cttable::CtTable;
use crate::ct::mobius::mobius_complete;
use crate::ct::project::project;
use crate::db::catalog::Database;
use crate::db::query::JoinStats;
use crate::error::{Error, Result};
use crate::meta::rvar::RVar;
use crate::metrics::memory::MemTracker;
use crate::metrics::timing::{Deadline, Phase, PhaseTimer};
use crate::strategies::cache::{digest_caches, CtCache};
use crate::strategies::common::{
    fill_positive_cache, narrow_to_ctx, var_pops, var_rels, LatticeCacheSource,
    LatticeCtx,
};
use crate::strategies::traits::{CountingStrategy, StrategyConfig, StrategyReport};

/// The PRECOUNT strategy.
pub struct Precount<'a> {
    db: &'a Database,
    #[allow(dead_code)]
    cfg: StrategyConfig,
    ctx: LatticeCtx,
    /// Positive ct-tables per lattice point + entity marginals.
    positive: CtCache,
    /// Complete (positive *and negative*) ct-tables per lattice point.
    complete: CtCache,
    timer: PhaseTimer,
    deadline: Deadline,
    join_stats: JoinStats,
    mem: MemTracker,
    families_served: u64,
    rows_generated: u64,
    prepared: bool,
}

impl<'a> Precount<'a> {
    /// Metadata phase runs here.
    pub fn new(db: &'a Database, cfg: StrategyConfig) -> Result<Self> {
        let deadline = Deadline::new(cfg.budget);
        let mut timer = PhaseTimer::default();
        let ctx = LatticeCtx::build(db, cfg.max_chain_length, &mut timer)?;
        Ok(Precount {
            db,
            cfg,
            ctx,
            positive: CtCache::new(),
            complete: CtCache::new(),
            timer,
            deadline,
            join_stats: JoinStats::default(),
            mem: MemTracker::default(),
            families_served: 0,
            rows_generated: 0,
            prepared: false,
        })
    }

    /// Complete-table cache key for a lattice point (shared with the
    /// parallel coordinator's PRECOUNT mode, which must generate the
    /// identical keys for its sharded complete cache).
    pub(crate) fn complete_key(
        p: &crate::lattice::LatticePoint,
    ) -> crate::strategies::cache::CacheKey {
        CtCache::key(&p.all_vars(), &p.pops)
    }
}

impl CountingStrategy for Precount<'_> {
    fn name(&self) -> &'static str {
        "PRECOUNT"
    }

    fn prepare(&mut self) -> Result<()> {
        if self.prepared {
            return Ok(());
        }
        // Positive phase: one JOIN per lattice point (Alg. 1 line 2).
        fill_positive_cache(
            self.db,
            &self.ctx,
            &mut self.positive,
            &mut self.timer,
            &self.deadline,
            &mut self.join_stats,
        )?;
        // Negative phase: Möbius Join per lattice point (Alg. 1 line 3).
        for i in 0..self.ctx.lattice.points.len() {
            self.deadline.check("negative ct (lattice)")?;
            let p = self.ctx.lattice.points[i].clone();
            let vars = p.all_vars();
            let complete = self.timer.time(Phase::Negative, || {
                let mut src = LatticeCacheSource {
                    db: self.db,
                    lattice: &self.ctx.lattice,
                    cache: &mut self.positive,
                };
                mobius_complete(&mut src, &vars, &p.pops)
            })?;
            self.rows_generated += complete.n_rows() as u64;
            self.complete.insert(Self::complete_key(&p), complete);
        }
        self.prepared = true;
        Ok(())
    }

    fn ct_for_family(&mut self, vars: &[RVar], ctx_pops: &[usize]) -> Result<CtTable> {
        if !self.prepared {
            self.prepare()?;
        }
        self.deadline.check("family projection")?;
        self.families_served += 1;
        let rels = var_rels(vars);
        let vpops = var_pops(&self.db.schema, vars);

        // Attribute-only family: cross product of cached marginals
        // (re-projected so the column order matches the request).
        if rels.is_empty() {
            let ct = self.timer.time(Phase::Positive, || {
                let mut src = LatticeCacheSource {
                    db: self.db,
                    lattice: &self.ctx.lattice,
                    cache: &mut self.positive,
                };
                let raw = crate::ct::mobius::g_subset(&mut src, &[], vars, ctx_pops)?;
                project(&raw, vars)
            })?;
            self.mem.observe_transient(ct.bytes());
            return Ok(ct);
        }

        let Some(p) = self.ctx.lattice.covering_point(&rels, &vpops).cloned() else {
            // No lattice point covers this family (its relationship set is
            // disconnected across chains).  The paper's PRECOUNT has no
            // answer here; we fall back to a family-level Möbius Join over
            // the *positive* cache — exactly the HYBRID move — so the
            // strategies stay interchangeable.  Counted as negative-ct
            // work since it is inclusion-exclusion at serve time.
            let ct = self.timer.time(Phase::Negative, || {
                let mut src = LatticeCacheSource {
                    db: self.db,
                    lattice: &self.ctx.lattice,
                    cache: &mut self.positive,
                };
                mobius_complete(&mut src, vars, ctx_pops)
            })?;
            self.rows_generated += ct.n_rows() as u64;
            self.mem.observe_transient(ct.bytes());
            return Ok(ct);
        };
        let key = Self::complete_key(&p);
        let full = self
            .complete
            .get(&key)
            .ok_or_else(|| Error::Strategy("complete ct missing (prepare?)".into()))?;

        // Projection only — Alg. 1 line 6 — then re-base the counts from
        // the point's populations onto the requested context.
        let mut ct = self.timer.time(Phase::Positive, || project(full, vars))?;
        narrow_to_ctx(self.db, &mut ct, &p.pops, ctx_pops, vars)?;
        self.mem.observe_transient(ct.bytes());
        Ok(ct)
    }

    fn report(&self) -> StrategyReport {
        let mut peak = self.mem;
        peak.merge_peak(&self.positive.mem);
        // complete tables live alongside the positives
        peak.peak_bytes = peak
            .peak_bytes
            .max(self.positive.mem.current_bytes + self.complete.mem.peak_bytes);
        StrategyReport {
            name: self.name().into(),
            timing: self.timer,
            join_stats: self.join_stats,
            cache_bytes: self.positive.bytes() + self.complete.bytes(),
            peak_ct_bytes: peak.peak_bytes,
            ct_rows_generated: self.rows_generated,
            families_served: self.families_served,
            cache_hits: self.complete.hits,
            cache_misses: self.complete.misses,
            ..Default::default()
        }
    }

    fn cache_digest(&self) -> u64 {
        digest_caches(&[(0, &self.positive), (1, &self.complete)])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ct::mobius::brute_force_complete;
    use crate::db::fixtures::university_db;

    fn family() -> Vec<RVar> {
        vec![
            RVar::RelInd { rel: 0 },
            RVar::RelAttr { rel: 0, attr: 1 },
            RVar::EntityAttr { et: 1, attr: 0 },
        ]
    }

    #[test]
    fn serves_families_after_prepare() {
        let db = university_db();
        let mut s = Precount::new(&db, StrategyConfig::default()).unwrap();
        s.prepare().unwrap();
        let ct = s.ct_for_family(&family(), &[0, 1]).unwrap();
        let brute = brute_force_complete(&db, &family(), &[0, 1]).unwrap();
        for (v, c) in brute.iter_rows() {
            assert_eq!(ct.get(&v).unwrap(), c);
        }
        let rep = s.report();
        assert_eq!(rep.families_served, 1);
        assert!(rep.timing.negative > std::time::Duration::ZERO);
        assert!(rep.ct_rows_generated > 0);
        assert!(rep.peak_ct_bytes > 0);
    }

    #[test]
    fn wider_context_scaling() {
        // family over (P,S) asked in the (P,S,C) context
        let db = university_db();
        let mut s = Precount::new(&db, StrategyConfig::default()).unwrap();
        let narrow = s.ct_for_family(&family(), &[0, 1]).unwrap();
        let wide = s.ct_for_family(&family(), &[0, 1, 2]).unwrap();
        let c = db.population(2) as i128;
        for (v, n) in narrow.iter_rows() {
            assert_eq!(wide.get(&v).unwrap(), n * c);
        }
    }

    #[test]
    fn attr_only_family() {
        let db = university_db();
        let mut s = Precount::new(&db, StrategyConfig::default()).unwrap();
        let vars = vec![RVar::EntityAttr { et: 0, attr: 0 }];
        let ct = s.ct_for_family(&vars, &[0, 1]).unwrap();
        // 12 professors x 19 students; popularity p%3 -> 4 each x 19
        assert_eq!(ct.get(&[0]).unwrap(), 4 * 19);
        assert_eq!(ct.total().unwrap() as u64, db.population_product(&[0, 1]));
    }

    #[test]
    fn uncoverable_family_errors() {
        let db = university_db();
        let cfg = StrategyConfig { max_chain_length: 1, ..Default::default() };
        let mut s = Precount::new(&db, cfg).unwrap();
        // needs both rels -> chain length 2 > max 1
        let vars = vec![RVar::RelInd { rel: 0 }, RVar::RelInd { rel: 1 }];
        assert!(s.ct_for_family(&vars, &[0, 1, 2]).is_err());
    }
}
