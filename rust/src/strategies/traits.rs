//! The strategy interface and its configuration/report types.

use std::time::Duration;

use crate::ct::cttable::CtTable;
use crate::db::query::JoinStats;
use crate::error::Result;
use crate::meta::rvar::RVar;
use crate::metrics::timing::PhaseTimer;

/// Configuration shared by all strategies.
#[derive(Clone, Copy, Debug)]
pub struct StrategyConfig {
    /// Maximum relationship-chain length in the lattice (FACTORBASE
    /// default: 3).
    pub max_chain_length: usize,
    /// Optional wall-clock budget; exceeded -> `Error::Timeout` (the
    /// paper's 100-minute Slurm limit).
    pub budget: Option<Duration>,
    /// Cache family-level ct-tables on first use (post-counting caching).
    pub family_cache: bool,
}

impl Default for StrategyConfig {
    fn default() -> Self {
        StrategyConfig { max_chain_length: 3, budget: None, family_cache: true }
    }
}

/// Cumulative counters a strategy reports after serving a workload.
#[derive(Clone, Debug, Default)]
pub struct StrategyReport {
    pub name: String,
    pub timing: PhaseTimer,
    pub join_stats: JoinStats,
    /// Exact bytes currently held in caches.
    pub cache_bytes: usize,
    /// Peak of (cache + transient ct) bytes — the Figure 4 metric.
    pub peak_ct_bytes: usize,
    /// Total rows over all ct-tables generated — the Table 5 metric.
    pub ct_rows_generated: u64,
    /// Families served.
    pub families_served: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
}

/// A count-caching strategy: serves complete ct-tables for families.
pub trait CountingStrategy {
    /// Strategy name (PRECOUNT / ONDEMAND / HYBRID).
    fn name(&self) -> &'static str;

    /// Pre-model-search preparation.  PRECOUNT builds complete lattice
    /// ct-tables here; HYBRID builds positive ones; ONDEMAND does
    /// nothing.
    fn prepare(&mut self) -> Result<()>;

    /// Complete ct-table over `vars` with grounding population
    /// `ctx_pops` (the lattice point's populations during search).
    fn ct_for_family(&mut self, vars: &[RVar], ctx_pops: &[usize]) -> Result<CtTable>;

    /// Metrics snapshot.
    fn report(&self) -> StrategyReport;
}
