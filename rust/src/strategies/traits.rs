//! The strategy interface and its configuration/report types.

use std::time::Duration;

use crate::ct::cttable::CtTable;
use crate::db::query::JoinStats;
use crate::error::Result;
use crate::estimate::sampler::EstimatorConfig;
use crate::meta::rvar::RVar;
use crate::metrics::timing::PhaseTimer;

/// Configuration shared by all strategies.
#[derive(Clone, Copy, Debug)]
pub struct StrategyConfig {
    /// Maximum relationship-chain length in the lattice (FACTORBASE
    /// default: 3).
    pub max_chain_length: usize,
    /// Optional wall-clock budget; exceeded -> `Error::Timeout` (the
    /// paper's 100-minute Slurm limit).
    pub budget: Option<Duration>,
    /// Cache family-level ct-tables on first use (post-counting caching).
    pub family_cache: bool,
    /// ADAPTIVE only: cap (in bytes) on the estimated resident size of
    /// pre-counted ct-tables.  `None` = unlimited (plan everything,
    /// PRECOUNT-equivalent); `Some(0)` = pre-count nothing
    /// (ONDEMAND-equivalent).  The fixed strategies ignore it.
    pub mem_budget: Option<u64>,
    /// ADAPTIVE only: the cardinality estimator's seed/walks/exhaustive
    /// settings (see [`crate::estimate::EstimatorConfig`]).
    pub estimator: EstimatorConfig,
}

impl Default for StrategyConfig {
    fn default() -> Self {
        StrategyConfig {
            max_chain_length: 3,
            budget: None,
            family_cache: true,
            mem_budget: None,
            estimator: EstimatorConfig::default(),
        }
    }
}

/// Cumulative counters a strategy reports after serving a workload.
#[derive(Clone, Debug, Default)]
pub struct StrategyReport {
    pub name: String,
    pub timing: PhaseTimer,
    pub join_stats: JoinStats,
    /// Exact bytes currently held in caches.
    pub cache_bytes: usize,
    /// Peak of (cache + transient ct) bytes — the Figure 4 metric.
    pub peak_ct_bytes: usize,
    /// Total rows over all ct-tables generated — the Table 5 metric.
    pub ct_rows_generated: u64,
    /// Families served.
    pub families_served: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// ADAPTIVE plan accounting: lattice points planned for positive
    /// pre-counting (0 for the fixed strategies).
    pub planned_positive: u64,
    /// Lattice points planned for complete (negative-included)
    /// pre-counting.
    pub planned_complete: u64,
    /// The plan's estimated resident-cache bytes.
    pub plan_est_bytes: u64,
    /// Random walks the plan's cardinality estimators consumed.
    pub estimator_walks: u64,
}

impl StrategyReport {
    /// Merge another report into this one (used by the parallel
    /// coordinator to fold per-worker shard reports into a single view).
    ///
    /// Additive counters sum; timings sum (giving a CPU-time view when
    /// the inputs ran concurrently); cache byte levels sum because shards
    /// hold disjoint tables; peaks sum for the same reason — the shards'
    /// caches coexist in one process, so the worst case is their
    /// simultaneous residency.
    pub fn merge(&mut self, other: &StrategyReport) {
        if self.name.is_empty() {
            self.name = other.name.clone();
        }
        self.timing.merge(&other.timing);
        self.join_stats.merge(&other.join_stats);
        self.cache_bytes += other.cache_bytes;
        self.peak_ct_bytes += other.peak_ct_bytes;
        self.ct_rows_generated += other.ct_rows_generated;
        self.families_served += other.families_served;
        self.cache_hits += other.cache_hits;
        self.cache_misses += other.cache_misses;
        // Plan accounting describes the one shared plan, not per-shard
        // work, so folding reports takes the maximum instead of summing.
        self.planned_positive = self.planned_positive.max(other.planned_positive);
        self.planned_complete = self.planned_complete.max(other.planned_complete);
        self.plan_est_bytes = self.plan_est_bytes.max(other.plan_est_bytes);
        self.estimator_walks = self.estimator_walks.max(other.estimator_walks);
    }
}

/// A count-caching strategy: serves complete ct-tables for families.
pub trait CountingStrategy {
    /// Strategy name (PRECOUNT / ONDEMAND / HYBRID).
    fn name(&self) -> &'static str;

    /// Pre-model-search preparation.  PRECOUNT builds complete lattice
    /// ct-tables here; HYBRID builds positive ones; ONDEMAND does
    /// nothing.
    fn prepare(&mut self) -> Result<()>;

    /// Complete ct-table over `vars` with grounding population
    /// `ctx_pops` (the lattice point's populations during search).
    fn ct_for_family(&mut self, vars: &[RVar], ctx_pops: &[usize]) -> Result<CtTable>;

    /// Complete ct-tables for a batch of families, in request order.
    ///
    /// The default implementation serves the batch sequentially through
    /// [`CountingStrategy::ct_for_family`]; the parallel coordinator
    /// overrides it to fan the batch out across worker shards.  Callers
    /// with several independent requests (the hill climb's candidate
    /// neighborhood) should prefer this entry point.
    fn ct_for_families(&mut self, reqs: &[FamilyRequest]) -> Result<Vec<CtTable>> {
        reqs.iter().map(|r| self.ct_for_family(&r.vars, &r.ctx_pops)).collect()
    }

    /// Metrics snapshot.
    fn report(&self) -> StrategyReport;

    /// Deterministic digest of every resident cache, via
    /// [`crate::strategies::cache::digest_caches`] with the shared tag
    /// scheme (0 = positive lattice tables + entity marginals, 1 =
    /// complete lattice tables, 2 = family tables).  The
    /// backend-equivalence witness: `--backend hash` and `--backend
    /// csr` must produce the identical digest for the same strategy and
    /// worker count (asserted by tests and the CI gate).
    fn cache_digest(&self) -> u64;
}

/// One family-count request: the family's variables plus the population
/// context its counts must range over.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct FamilyRequest {
    pub vars: Vec<RVar>,
    pub ctx_pops: Vec<usize>,
}

impl FamilyRequest {
    /// Build a request from borrowed slices.
    pub fn new(vars: &[RVar], ctx_pops: &[usize]) -> Self {
        FamilyRequest { vars: vars.to_vec(), ctx_pops: ctx_pops.to_vec() }
    }
}
