//! A micro-benchmark harness (criterion is not available offline):
//! warmup + timed iterations with mean / stddev / min, and a tabular
//! reporter shared by all `cargo bench` targets.

use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub iters: u32,
    pub mean: Duration,
    pub stddev: Duration,
    pub min: Duration,
}

impl Measurement {
    pub fn mean_s(&self) -> f64 {
        self.mean.as_secs_f64()
    }
}

/// Benchmark a closure: `warmup` untimed runs, then `iters` timed runs.
pub fn bench<T>(
    name: &str,
    warmup: u32,
    iters: u32,
    mut f: impl FnMut() -> T,
) -> Measurement {
    assert!(iters > 0);
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>()
        / samples.len() as f64;
    let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    Measurement {
        name: name.to_string(),
        iters,
        mean: Duration::from_secs_f64(mean),
        stddev: Duration::from_secs_f64(var.sqrt()),
        min: Duration::from_secs_f64(min),
    }
}

/// Render measurements as an aligned table.
pub fn render(title: &str, ms: &[Measurement]) -> String {
    let mut out = format!("== {title} ==\n");
    out.push_str(&format!(
        "{:<44} {:>6} {:>12} {:>12} {:>12}\n",
        "benchmark", "iters", "mean_s", "stddev_s", "min_s"
    ));
    for m in ms {
        out.push_str(&format!(
            "{:<44} {:>6} {:>12.6} {:>12.6} {:>12.6}\n",
            m.name,
            m.iters,
            m.mean.as_secs_f64(),
            m.stddev.as_secs_f64(),
            m.min.as_secs_f64()
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let m = bench("spin", 1, 5, || {
            let mut x = 0u64;
            for i in 0..10_000 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert!(m.mean > Duration::ZERO);
        assert!(m.min <= m.mean);
        assert_eq!(m.iters, 5);
        let r = render("t", &[m]);
        assert!(r.contains("spin"));
    }
}
