//! A tiny CLI argument parser: subcommand + `--key value` / `--flag`
//! options (clap is not available offline).

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// First non-flag token.
    pub command: Option<String>,
    /// Remaining positional tokens.
    pub positional: Vec<String>,
    /// `--key value` and `--flag` (value `"true"`).
    pub options: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of tokens (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    return Err(Error::Data("bad flag `--`".into()));
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.options.insert(name.to_string(), v);
                } else {
                    out.options.insert(name.to_string(), "true".to_string());
                }
            } else if out.command.is_none() {
                out.command = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Data(format!("--{key} expects an integer, got {v:?}"))),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Data(format!("--{key} expects a number, got {v:?}"))),
        }
    }

    pub fn has(&self, key: &str) -> bool {
        self.options.contains_key(key)
    }

    /// The `--workers` option: absent -> 1 (sequential), `auto` or `0`
    /// -> 0 (the coordinator resolves 0 to all available cores), else a
    /// positive integer.
    pub fn workers(&self) -> Result<usize> {
        match self.get("workers") {
            None => Ok(1),
            Some("auto") | Some("0") => Ok(0),
            Some(v) => match v.parse::<usize>() {
                Ok(n) if n >= 1 => Ok(n),
                _ => Err(Error::Data(format!(
                    "--workers expects a positive integer or `auto`, got {v:?}"
                ))),
            },
        }
    }

    /// The `--mem-budget` option (ADAPTIVE): bytes with an optional
    /// `k`/`m`/`g` suffix (powers of 1024).  Absent, `inf` or
    /// `unlimited` -> `None` (plan everything); `0` -> `Some(0)`
    /// (pre-count nothing).
    pub fn mem_budget(&self) -> Result<Option<u64>> {
        match self.get("mem-budget") {
            None => Ok(None),
            Some(v) => parse_bytes(v),
        }
    }
}

/// Parse a byte count with an optional binary-unit suffix.
pub fn parse_bytes(v: &str) -> Result<Option<u64>> {
    let t = v.trim().to_ascii_lowercase();
    if t == "inf" || t == "unlimited" || t == "none" {
        return Ok(None);
    }
    let (digits, mult) = match t.strip_suffix(&['k', 'm', 'g'][..]) {
        Some(d) => {
            let mult = match t.as_bytes()[t.len() - 1] {
                b'k' => 1u64 << 10,
                b'm' => 1u64 << 20,
                _ => 1u64 << 30,
            };
            (d, mult)
        }
        None => (t.as_str(), 1u64),
    };
    digits
        .trim()
        .parse::<u64>()
        .ok()
        .and_then(|n| n.checked_mul(mult))
        .map(Some)
        .ok_or_else(|| {
            Error::Data(format!(
                "--mem-budget expects BYTES[k|m|g] or `inf`, got {v:?}"
            ))
        })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("learn --db imdb --scale 0.1 extra --verbose");
        assert_eq!(a.command.as_deref(), Some("learn"));
        assert_eq!(a.get("db"), Some("imdb"));
        assert_eq!(a.get_f64("scale", 1.0).unwrap(), 0.1);
        assert_eq!(a.get("verbose"), Some("true"));
        assert_eq!(a.positional, vec!["extra".to_string()]);
    }

    #[test]
    fn equals_form_and_defaults() {
        let a = parse("exp --out=/tmp/x --n 3");
        assert_eq!(a.get("out"), Some("/tmp/x"));
        assert_eq!(a.get_usize("n", 9).unwrap(), 3);
        assert_eq!(a.get_usize("missing", 9).unwrap(), 9);
        assert!(a.get_usize("out", 1).is_err());
    }

    #[test]
    fn trailing_flag() {
        let a = parse("run --fast");
        assert!(a.has("fast"));
    }

    #[test]
    fn workers_parsing() {
        assert_eq!(parse("learn").workers().unwrap(), 1);
        assert_eq!(parse("learn --workers 4").workers().unwrap(), 4);
        assert_eq!(parse("learn --workers auto").workers().unwrap(), 0);
        assert_eq!(parse("learn --workers 0").workers().unwrap(), 0);
        assert!(parse("learn --workers nope").workers().is_err());
    }

    #[test]
    fn mem_budget_parsing() {
        assert_eq!(parse("count").mem_budget().unwrap(), None);
        assert_eq!(parse("count --mem-budget inf").mem_budget().unwrap(), None);
        assert_eq!(parse("count --mem-budget 0").mem_budget().unwrap(), Some(0));
        assert_eq!(parse("count --mem-budget 4096").mem_budget().unwrap(), Some(4096));
        assert_eq!(
            parse("count --mem-budget 64m").mem_budget().unwrap(),
            Some(64 << 20)
        );
        assert_eq!(parse("count --mem-budget 2K").mem_budget().unwrap(), Some(2048));
        assert_eq!(
            parse("count --mem-budget 1g").mem_budget().unwrap(),
            Some(1 << 30)
        );
        assert!(parse("count --mem-budget lots").mem_budget().is_err());
    }
}
