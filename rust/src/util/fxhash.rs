//! An in-tree port of the FxHash algorithm (rustc's non-cryptographic
//! hasher; Firefox lineage), so the crate builds with zero external
//! dependencies while keeping the unseeded, cross-process-stable hashing
//! that the coordinator's deterministic shard routing relies on
//! ([`crate::coordinator::shard::shard_of`]).
//!
//! The byte-stream mixing follows the published algorithm: fold each
//! `usize`-sized word into the state with a rotate, xor, and multiply by
//! a golden-ratio-derived constant.  Identical input always hashes to
//! the identical value on a given pointer width — there is no per-process
//! seed, unlike `std`'s SipHash.

use std::collections::{HashMap, HashSet};
use std::hash::{BuildHasherDefault, Hasher};

/// Hash map keyed by [`FxHasher`].  Drop-in for the `rustc_hash` crate's
/// type of the same name.
pub type FxHashMap<K, V> = HashMap<K, V, BuildHasherDefault<FxHasher>>;

/// Hash set keyed by [`FxHasher`].
pub type FxHashSet<T> = HashSet<T, BuildHasherDefault<FxHasher>>;

const SEED64: u64 = 0x51_7c_c1_b7_27_22_0a_95;
const ROTATE: u32 = 5;

/// The FxHash word-at-a-time hasher.
#[derive(Clone, Copy, Debug, Default)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add_to_hash(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(ROTATE) ^ word).wrapping_mul(SEED64);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut chunks = bytes.chunks_exact(8);
        for c in &mut chunks {
            self.add_to_hash(u64::from_le_bytes(c.try_into().expect("8-byte chunk")));
        }
        let rest = chunks.remainder();
        if !rest.is_empty() {
            let mut buf = [0u8; 8];
            buf[..rest.len()].copy_from_slice(rest);
            self.add_to_hash(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u8(&mut self, i: u8) {
        self.add_to_hash(i as u64);
    }

    #[inline]
    fn write_u16(&mut self, i: u16) {
        self.add_to_hash(i as u64);
    }

    #[inline]
    fn write_u32(&mut self, i: u32) {
        self.add_to_hash(i as u64);
    }

    #[inline]
    fn write_u64(&mut self, i: u64) {
        self.add_to_hash(i);
    }

    #[inline]
    fn write_u128(&mut self, i: u128) {
        self.add_to_hash(i as u64);
        self.add_to_hash((i >> 64) as u64);
    }

    #[inline]
    fn write_usize(&mut self, i: usize) {
        self.add_to_hash(i as u64);
    }

    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::hash::Hash;

    fn hash_of<T: Hash>(v: &T) -> u64 {
        let mut h = FxHasher::default();
        v.hash(&mut h);
        h.finish()
    }

    #[test]
    fn deterministic_and_unseeded() {
        let key = (vec![1usize, 2, 3], vec![0usize, 1]);
        assert_eq!(hash_of(&key), hash_of(&key.clone()));
        assert_ne!(hash_of(&1u64), hash_of(&2u64));
        // stable across hasher instances (no per-process seed)
        let a = hash_of(&"positive ct".to_string());
        let b = hash_of(&"positive ct".to_string());
        assert_eq!(a, b);
    }

    #[test]
    fn map_and_set_work() {
        let mut m: FxHashMap<u64, u64> = FxHashMap::default();
        for i in 0..100 {
            m.insert(i, i * i);
        }
        assert_eq!(m.len(), 100);
        assert_eq!(m[&7], 49);
        let s: FxHashSet<u64> = (0..50).collect();
        assert!(s.contains(&49) && !s.contains(&50));
    }

    #[test]
    fn partial_tail_bytes_mix() {
        // 9 bytes exercises the chunk + remainder path
        let mut h = FxHasher::default();
        h.write(&[1, 2, 3, 4, 5, 6, 7, 8, 9]);
        let nine = h.finish();
        let mut h2 = FxHasher::default();
        h2.write(&[1, 2, 3, 4, 5, 6, 7, 8]);
        assert_ne!(nine, h2.finish());
    }
}
