//! A small, strict JSON parser and writer (RFC 8259 subset sufficient
//! for our artifact manifests and schema files).
//!
//! Supports all JSON value kinds; numbers are held as f64 with exact
//! round-trip for integers up to 2^53 (our manifests only contain small
//! integers and hashes-as-strings).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Object with stable (sorted) key order.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Required-field helpers producing manifest-shaped errors.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| Error::Manifest(format!("missing field {key:?}")))
    }

    // -- construction helpers ----------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Serialize (compact).
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn err(&self, msg: &str) -> Error {
        Error::Manifest(format!("json parse error at byte {}: {msg}", self.i))
    }

    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {s}")))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => {
                self.i += 1;
                let mut v = Vec::new();
                self.ws();
                if self.peek() == Some(b']') {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                loop {
                    self.ws();
                    v.push(self.value()?);
                    self.ws();
                    match self.peek() {
                        Some(b',') => self.i += 1,
                        Some(b']') => {
                            self.i += 1;
                            return Ok(Json::Arr(v));
                        }
                        _ => return Err(self.err("expected , or ]")),
                    }
                }
            }
            b'{' => {
                self.i += 1;
                let mut m = BTreeMap::new();
                self.ws();
                if self.peek() == Some(b'}') {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                loop {
                    self.ws();
                    let k = self.string()?;
                    self.ws();
                    self.eat(b':')?;
                    self.ws();
                    m.insert(k, self.value()?);
                    self.ws();
                    match self.peek() {
                        Some(b',') => self.i += 1,
                        Some(b'}') => {
                            self.i += 1;
                            return Ok(Json::Obj(m));
                        }
                        _ => return Err(self.err("expected , or }")),
                    }
                }
            }
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected {:?}", c as char))),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = *self.b.get(self.i).ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e =
                        *self.b.get(self.i).ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i..self.i + 4])
                                    .map_err(|_| self.err("bad \\u"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u"))?;
                            self.i += 4;
                            // (no surrogate-pair support; our files are BMP)
                            s.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                c => {
                    // collect raw utf-8 bytes
                    let start = self.i - 1;
                    let mut end = self.i;
                    if c >= 0x80 {
                        while end < self.b.len() && self.b[end] >= 0x80 {
                            end += 1;
                        }
                    }
                    let chunk = std::str::from_utf8(&self.b[start..end])
                        .map_err(|_| self.err("bad utf-8"))?;
                    s.push_str(chunk);
                    self.i = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| self.err("bad number"))?;
        txt.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{
          "format": "hlo-text",
          "artifacts": {
            "mobius": {
              "file": "mobius.hlo.txt",
              "inputs": [{"name": "g", "shape": [8, 8, 8, 1024], "dtype": "float64"}],
              "meta": {"d_pad": 8}
            }
          }
        }"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.get("format").unwrap().as_str().unwrap(), "hlo-text");
        let m = j.get("artifacts").unwrap().get("mobius").unwrap();
        let inp = &m.get("inputs").unwrap().as_arr().unwrap()[0];
        let shape: Vec<usize> = inp
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_usize().unwrap())
            .collect();
        assert_eq!(shape, vec![8, 8, 8, 1024]);
        assert_eq!(m.get("meta").unwrap().get("d_pad").unwrap().as_usize(), Some(8));
    }

    #[test]
    fn roundtrip() {
        let j = Json::obj(vec![
            ("a", Json::Arr(vec![Json::num(1), Json::num(2.5), Json::Null])),
            ("b", Json::str("x\"y\\z\nw")),
            ("c", Json::Bool(true)),
        ]);
        let s = j.dump();
        let back = Json::parse(&s).unwrap();
        assert_eq!(back, j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let j = Json::parse(r#""café ✓""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "café ✓");
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-12").unwrap().as_f64().unwrap(), -12.0);
        assert_eq!(Json::parse("3.5e2").unwrap().as_f64().unwrap(), 350.0);
        assert_eq!(Json::parse("42").unwrap().as_usize(), Some(42));
        assert_eq!(Json::parse("-1").unwrap().as_usize(), None);
    }
}
