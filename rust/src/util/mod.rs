//! In-tree substrates that would normally come from crates.io (this
//! image builds offline): a JSON parser/writer, a seeded PRNG, a CLI
//! argument parser, and a micro-benchmark harness.

pub mod bench;
pub mod cli;
pub mod json;
pub mod rng;
