//! In-tree substrates that would normally come from crates.io (this
//! image builds offline): a JSON parser/writer, a seeded PRNG, a CLI
//! argument parser, an FxHash implementation, and a micro-benchmark
//! harness.

pub mod bench;
pub mod cli;
pub mod fxhash;
pub mod json;
pub mod rng;
