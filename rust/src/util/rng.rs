//! A small deterministic PRNG (SplitMix64 seeding a xoshiro256**) used by
//! the synthetic dataset generators and the in-tree property tests.
//! Seeded runs are bit-reproducible across platforms.

/// xoshiro256** seeded via SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)` (n > 0), with modulo-bias rejection.
    #[inline]
    pub fn gen_range(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    pub fn gen_u32(&mut self, n: u32) -> u32 {
        self.gen_range(n as u64) as u32
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Skewed categorical value in `[0, card)`: a geometric-ish
    /// distribution so synthetic attributes have realistic non-uniform
    /// marginals.
    pub fn gen_skewed(&mut self, card: u32) -> u32 {
        debug_assert!(card > 0);
        let mut v = 0u32;
        while v + 1 < card && self.gen_bool(0.55) {
            v += 1;
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(8);
        assert_ne!(Rng::new(7).next_u64(), c.next_u64());
    }

    #[test]
    fn ranges_in_bounds() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            assert!(r.gen_range(7) < 7);
            let f = r.gen_f64();
            assert!((0.0..1.0).contains(&f));
            assert!(r.gen_skewed(4) < 4);
        }
        assert_eq!(r.gen_range(1), 0);
    }

    #[test]
    fn uniformish() {
        let mut r = Rng::new(42);
        let mut buckets = [0u32; 8];
        for _ in 0..8000 {
            buckets[r.gen_range(8) as usize] += 1;
        }
        for &b in &buckets {
            assert!((700..1300).contains(&b), "bucket {b}");
        }
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(3);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
        assert_ne!(v, s); // astronomically unlikely to be identity
    }
}
