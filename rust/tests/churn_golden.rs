//! Golden-fixture test for the `exp churn --json` output (the
//! runtime_artifacts.rs pattern: drive the public row generator + JSON
//! emitter and pin the machine-readable shape).
//!
//! Two guarantees:
//! - **schema stability** — every row carries exactly the golden key
//!   set, with the golden types, so downstream BENCH_churn.json readers
//!   never break silently;
//! - **seeded determinism** — every non-timing field is identical
//!   across two runs of the same (preset, scale, seed, fracs), and the
//!   delta/recount consistency bit is always true.

use std::time::Duration;

use relcount::bench::experiments::{churn_rows, ExpConfig};
use relcount::metrics::report::churn_rows_to_json;
use relcount::util::json::Json;

/// The golden key set of one BENCH_churn.json row, in sorted order.
const GOLDEN_KEYS: [&str; 16] = [
    "batch_ops",
    "cells_touched",
    "churn_frac",
    "consistent",
    "database",
    "delta_s",
    "digest",
    "entity_inserts",
    "link_deletes",
    "link_inserts",
    "points_delta_maintained",
    "points_recounted",
    "recount_s",
    "resident_bytes",
    "speedup",
    "workers",
];

/// Fields that must be bit-identical across seeded re-runs (everything
/// except the wall-clock measurements derived from them).
const DETERMINISTIC_KEYS: [&str; 12] = [
    "batch_ops",
    "cells_touched",
    "churn_frac",
    "consistent",
    "database",
    "digest",
    "entity_inserts",
    "link_deletes",
    "link_inserts",
    "points_delta_maintained",
    "points_recounted",
    "workers",
];

fn cfg() -> ExpConfig {
    ExpConfig {
        scale: 0.03,
        budget: Some(Duration::from_secs(120)),
        seed: 9,
        presets: &["uw"],
        ..Default::default()
    }
}

fn rows_json() -> Json {
    let rows = churn_rows(&cfg(), &[0.05, 0.1], 1).unwrap();
    let json = churn_rows_to_json(&rows);
    // the emitter's output must survive its own parser
    Json::parse(&json.dump()).unwrap()
}

#[test]
fn churn_json_rows_match_the_golden_schema() {
    let parsed = rows_json();
    let rows = parsed.as_arr().unwrap();
    assert_eq!(rows.len(), 2, "one row per churn fraction");
    for row in rows {
        let obj = row.as_obj().unwrap();
        let keys: Vec<&str> = obj.keys().map(|k| k.as_str()).collect();
        assert_eq!(keys, GOLDEN_KEYS, "key set drifted");
        // golden types
        assert!(row.get("database").unwrap().as_str().is_some());
        assert!(row.get("digest").unwrap().as_str().is_some());
        assert_eq!(row.get("digest").unwrap().as_str().unwrap().len(), 16);
        assert!(matches!(row.get("consistent").unwrap(), Json::Bool(_)));
        for num_key in [
            "batch_ops",
            "cells_touched",
            "churn_frac",
            "delta_s",
            "entity_inserts",
            "link_deletes",
            "link_inserts",
            "points_delta_maintained",
            "points_recounted",
            "recount_s",
            "resident_bytes",
            "speedup",
            "workers",
        ] {
            let v = row.get(num_key).unwrap().as_f64();
            assert!(v.is_some(), "{num_key} must be numeric");
            assert!(v.unwrap() >= 0.0, "{num_key} must be non-negative");
        }
        // every measurement doubles as a differential check
        assert_eq!(row.get("consistent").unwrap(), &Json::Bool(true));
    }
}

#[test]
fn churn_json_is_seed_deterministic_across_runs() {
    let a = rows_json();
    let b = rows_json();
    let (ra, rb) = (a.as_arr().unwrap(), b.as_arr().unwrap());
    assert_eq!(ra.len(), rb.len());
    for (x, y) in ra.iter().zip(rb) {
        for key in DETERMINISTIC_KEYS {
            assert_eq!(
                x.get(key).unwrap(),
                y.get(key).unwrap(),
                "field {key} must be seed-deterministic"
            );
        }
    }
}
