//! The coordinator's central invariant: worker counts are
//! interchangeable.  A 1-worker and a 4-worker run of every strategy
//! mode must produce bit-identical ct-tables, and structure learning
//! through the coordinator must reproduce the sequential strategies'
//! models and BDeu scores exactly.

use relcount::bench::driver::{run_coordinated, run_strategy, Workload};
use relcount::coordinator::{CoordinatorConfig, ParallelCoordinator};
use relcount::ct::cttable::CtTable;
use relcount::datagen::{generator::generate, presets::preset};
use relcount::db::catalog::Database;
use relcount::lattice::Lattice;
use relcount::learn::search::SearchConfig;
use relcount::meta::rvar::RVar;
use relcount::strategies::traits::{CountingStrategy, StrategyConfig};
use relcount::strategies::StrategyKind;

/// Seeded preset shared by every test in this file.
fn seeded_db() -> Database {
    let cfg = preset("uw", 0.02, 42).unwrap();
    generate(&cfg).unwrap()
}

fn coordinator(
    db: &Database,
    kind: StrategyKind,
    workers: usize,
) -> ParallelCoordinator<'_> {
    ParallelCoordinator::new(
        db,
        kind,
        CoordinatorConfig { workers, strategy: StrategyConfig::default() },
    )
    .unwrap()
}

/// Singleton and pair families over each lattice point's variable set
/// (the same enumeration strategy_equivalence.rs uses, bounded for time).
fn families_of(db: &Database) -> Vec<(Vec<RVar>, Vec<usize>)> {
    let lattice = Lattice::build(&db.schema, 3).unwrap();
    let mut out = Vec::new();
    for p in &lattice.points {
        let vars = p.all_vars();
        for i in 0..vars.len() {
            out.push((vec![vars[i]], p.pops.clone()));
            for j in (i + 1)..vars.len() {
                out.push((vec![vars[i], vars[j]], p.pops.clone()));
            }
        }
    }
    out
}

fn assert_tables_equal(a: &CtTable, b: &CtTable, what: &str) {
    assert_eq!(a.n_rows(), b.n_rows(), "{what}: row count");
    for (vals, c) in b.iter_rows() {
        assert_eq!(a.get(&vals).unwrap(), c, "{what} at {vals:?}");
    }
}

#[test]
fn one_and_four_workers_serve_identical_tables() {
    let db = seeded_db();
    let fams = families_of(&db);
    assert!(fams.len() > 20);
    for kind in StrategyKind::ALL {
        let mut w1 = coordinator(&db, kind, 1);
        let mut w4 = coordinator(&db, kind, 4);
        for (vars, ctx) in &fams {
            let a = w1.ct_for_family(vars, ctx).unwrap();
            let b = w4.ct_for_family(vars, ctx).unwrap();
            assert_tables_equal(&a, &b, &format!("{kind:?} {vars:?}"));
        }
    }
}

#[test]
fn coordinator_matches_sequential_strategies() {
    let db = seeded_db();
    let fams = families_of(&db);
    for kind in StrategyKind::ALL {
        let mut seq = kind.build(&db, StrategyConfig::default()).unwrap();
        let mut par = coordinator(&db, kind, 4);
        for (vars, ctx) in &fams {
            let a = seq.ct_for_family(vars, ctx).unwrap();
            let b = par.ct_for_family(vars, ctx).unwrap();
            assert_tables_equal(&b, &a, &format!("{kind:?} {vars:?}"));
        }
    }
}

#[test]
fn batched_serving_matches_single_requests() {
    use relcount::strategies::traits::FamilyRequest;
    let db = seeded_db();
    let reqs: Vec<FamilyRequest> = families_of(&db)
        .into_iter()
        .map(|(vars, ctx)| FamilyRequest { vars, ctx_pops: ctx })
        .collect();
    for kind in StrategyKind::ALL {
        let mut batch = coordinator(&db, kind, 4);
        let tables = batch.ct_for_families(&reqs).unwrap();
        assert_eq!(tables.len(), reqs.len());
        let mut single = coordinator(&db, kind, 1);
        for (r, t) in reqs.iter().zip(&tables) {
            let one = single.ct_for_family(&r.vars, &r.ctx_pops).unwrap();
            assert_tables_equal(t, &one, &format!("{kind:?} {:?}", r.vars));
        }
    }
}

#[test]
fn learned_models_and_bdeu_scores_identical_across_workers() {
    let db = seeded_db();
    let cfg = SearchConfig::default();
    for kind in StrategyKind::ALL {
        let seq = run_strategy(&db, "uw", kind, Workload::Learn(cfg), None)
            .unwrap()
            .model
            .unwrap();
        for workers in [1usize, 4] {
            let par = run_coordinated(
                &db,
                "uw",
                kind,
                Workload::Learn(cfg),
                None,
                workers,
            )
            .unwrap()
            .model
            .unwrap();
            assert_eq!(par.bn.nodes, seq.bn.nodes, "{kind:?} w={workers}");
            assert_eq!(par.bn.parents, seq.bn.parents, "{kind:?} w={workers}");
            // identical ct-tables -> identical BDeu arithmetic
            assert_eq!(
                par.total_score.to_bits(),
                seq.total_score.to_bits(),
                "{kind:?} w={workers}: {} vs {}",
                par.total_score,
                seq.total_score
            );
        }
    }
}

#[test]
fn prepare_metrics_match_sequential_counts() {
    // The parallel pre-count executes the same queries and generates the
    // same rows/bytes as the sequential fill, whatever the worker count.
    let db = seeded_db();
    for kind in [StrategyKind::Precount, StrategyKind::Hybrid] {
        let mut seq = kind.build(&db, StrategyConfig::default()).unwrap();
        seq.prepare().unwrap();
        let s = seq.report();
        for workers in [1usize, 4] {
            let mut par = coordinator(&db, kind, workers);
            par.prepare().unwrap();
            let p = par.report();
            assert_eq!(
                p.join_stats.chain_queries, s.join_stats.chain_queries,
                "{kind:?} w={workers}"
            );
            assert_eq!(
                p.join_stats.rows_enumerated, s.join_stats.rows_enumerated,
                "{kind:?} w={workers}"
            );
            assert_eq!(
                p.ct_rows_generated, s.ct_rows_generated,
                "{kind:?} w={workers}"
            );
            assert_eq!(p.cache_bytes, s.cache_bytes, "{kind:?} w={workers}");
        }
    }
}
