//! The delta maintenance subsystem's differential contract (mirrors
//! strategy_equivalence.rs): after arbitrary seeded insert/delete
//! sequences, delta-maintained counts must be **bit-identical** to
//! from-scratch recounts — for all four strategies rebuilt on the
//! mutated data, sequentially and under `--workers 4`, including
//! learned structures and BDeu score bits.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use relcount::ct::cttable::CtTable;
use relcount::datagen::churn::churn_batch;
use relcount::datagen::{generator::generate, presets::preset};
use relcount::db::catalog::Database;
use relcount::delta::{DeltaBatch, DeltaOp, MaintainConfig, MaintainedCounts, MaintenanceMode};
use relcount::lattice::Lattice;
use relcount::learn::search::SearchConfig;
use relcount::meta::rvar::RVar;
use relcount::serve::{Generation, ServeEngine};
use relcount::strategies::traits::{CountingStrategy, StrategyConfig};
use relcount::strategies::StrategyKind;

/// Singleton and pair families over each lattice point's variable set
/// (the enumeration strategy_equivalence.rs uses, bounded for time).
fn families_of(db: &Database) -> Vec<(Vec<RVar>, Vec<usize>)> {
    let lattice = Lattice::build(&db.schema, 3).unwrap();
    let mut out = Vec::new();
    for p in &lattice.points {
        let vars = p.all_vars();
        for i in 0..vars.len() {
            out.push((vec![vars[i]], p.pops.clone()));
            for j in (i + 1)..vars.len() {
                out.push((vec![vars[i], vars[j]], p.pops.clone()));
            }
        }
    }
    out
}

fn assert_tables_equal(a: &CtTable, b: &CtTable, what: &str) {
    assert_eq!(a.n_rows(), b.n_rows(), "{what}: row count");
    for (vals, c) in b.iter_rows() {
        assert_eq!(a.get(&vals).unwrap(), c, "{what} at {vals:?}");
    }
}

/// Rebuild a fresh, from-scratch database from the maintained state's
/// current tables (fresh validation + fresh indexes — no maintained
/// structure is reused).
fn rebuild(m: &MaintainedCounts) -> Database {
    Database::new(
        m.db().schema.clone(),
        m.db().entities.clone(),
        m.db().rels.clone(),
    )
    .unwrap()
}

fn seeded_db(name: &str) -> Database {
    // 0.05 keeps the runs fast while giving batches enough link rows to
    // mix inserts, deletes and the occasional entity insert
    generate(&preset(name, 0.05, 42).unwrap()).unwrap()
}

#[test]
fn maintained_counts_match_all_four_strategies_after_churn() {
    for name in ["uw", "hepatitis"] {
        let db = seeded_db(name);
        let mut m = MaintainedCounts::build(db, MaintainConfig::default()).unwrap();
        for step in 0..3u64 {
            let batch = churn_batch(m.db(), 0.4, 1_000 + step);
            m.apply(&batch).unwrap();
            let fresh = rebuild(&m);
            let fams = families_of(&fresh);
            let mut strategies: Vec<Box<dyn CountingStrategy>> =
                StrategyKind::ALL_WITH_ADAPTIVE
                    .iter()
                    .map(|k| k.build(&fresh, StrategyConfig::default()).unwrap())
                    .collect();
            for (vars, ctx) in &fams {
                let maintained = m.ct_for_family(vars, ctx).unwrap();
                for s in strategies.iter_mut() {
                    let want = s.ct_for_family(vars, ctx).unwrap();
                    assert_tables_equal(
                        &maintained,
                        &want,
                        &format!("{name} step {step} {} {vars:?}", s.name()),
                    );
                }
            }
        }
    }
}

#[test]
fn partial_residency_plans_stay_exact_after_churn() {
    // hybrid-equivalent budget (positives only) and a half budget: serve
    // paths mix projections and fresh joins, counts must not care
    let db = seeded_db("uw");
    let probe =
        MaintainedCounts::build(db.clone(), MaintainConfig::default()).unwrap();
    let hb = probe.plan().hybrid_budget();
    for budget in [Some(hb), Some(hb / 2), Some(0)] {
        let cfg = MaintainConfig { mem_budget: budget, ..Default::default() };
        let mut m = MaintainedCounts::build(db.clone(), cfg).unwrap();
        let batch = churn_batch(m.db(), 0.4, 77);
        m.apply(&batch).unwrap();
        let fresh = rebuild(&m);
        let mut reference =
            StrategyKind::OnDemand.build(&fresh, StrategyConfig::default()).unwrap();
        for (vars, ctx) in families_of(&fresh) {
            let got = m.ct_for_family(&vars, &ctx).unwrap();
            let want = reference.ct_for_family(&vars, &ctx).unwrap();
            assert_tables_equal(&got, &want, &format!("budget {budget:?} {vars:?}"));
        }
    }
}

#[test]
fn four_workers_maintain_bit_identical_caches() {
    for name in ["uw", "hepatitis"] {
        let db = seeded_db(name);
        let mut seq = MaintainedCounts::build(
            db.clone(),
            MaintainConfig { workers: 1, ..Default::default() },
        )
        .unwrap();
        let mut par = MaintainedCounts::build(
            db,
            MaintainConfig { workers: 4, ..Default::default() },
        )
        .unwrap();
        assert_eq!(seq.digest(), par.digest(), "{name}: build");
        for step in 0..3u64 {
            let batch = churn_batch(seq.db(), 0.4, 2_000 + step);
            seq.apply(&batch).unwrap();
            par.apply(&batch).unwrap();
            assert_eq!(seq.digest(), par.digest(), "{name}: step {step}");
        }
        // and the served tables agree with a fresh strategy
        let fresh = rebuild(&par);
        let mut reference =
            StrategyKind::Hybrid.build(&fresh, StrategyConfig::default()).unwrap();
        for (vars, ctx) in families_of(&fresh).into_iter().take(40) {
            let got = par.ct_for_family(&vars, &ctx).unwrap();
            let want = reference.ct_for_family(&vars, &ctx).unwrap();
            assert_tables_equal(&got, &want, &format!("{name} w=4 {vars:?}"));
        }
    }
}

#[test]
fn hash_and_csr_backends_maintain_bit_identical_caches() {
    // the storage-engine contract: under seeded churn the maintained
    // digests stay identical across backends, for 1 and 4 workers, and
    // the CSR writer ends every batch with its overlay compacted
    use relcount::db::index::Backend;
    for workers in [1usize, 4] {
        let csr_db = seeded_db("uw");
        let mut hash_db = csr_db.clone();
        hash_db.set_backend(Backend::Hash).unwrap();
        let cfg = MaintainConfig { workers, ..Default::default() };
        let mut csr = MaintainedCounts::build(csr_db, cfg).unwrap();
        let mut hash = MaintainedCounts::build(hash_db, cfg).unwrap();
        assert_eq!(csr.digest(), hash.digest(), "workers {workers}: build");
        for step in 0..3u64 {
            let batch = churn_batch(csr.db(), 0.3, 7_000 + step);
            csr.apply(&batch).unwrap();
            hash.apply(&batch).unwrap();
            assert_eq!(
                csr.digest(),
                hash.digest(),
                "workers {workers}: step {step}"
            );
            assert_eq!(
                csr.db().index_overlay_len(),
                0,
                "workers {workers}: overlay not compacted at end-of-batch"
            );
        }
        // served tables agree across backends after the churn
        let fams = families_of(csr.db());
        for (vars, ctx) in fams.into_iter().take(30) {
            let a = csr.ct_for_family(&vars, &ctx).unwrap();
            let b = hash.ct_for_family(&vars, &ctx).unwrap();
            assert_tables_equal(&a, &b, &format!("w={workers} {vars:?}"));
        }
    }
}

#[test]
fn learned_structures_and_bdeu_bits_survive_churn() {
    let db = seeded_db("uw");
    let mut m = MaintainedCounts::build(db, MaintainConfig::default()).unwrap();
    for step in 0..2u64 {
        let batch = churn_batch(m.db(), 0.3, 3_000 + step);
        m.apply(&batch).unwrap();
    }
    let cfg = SearchConfig::default();
    let maintained = m.learn(cfg).unwrap();

    let fresh = rebuild(&m);
    for kind in [StrategyKind::Hybrid, StrategyKind::Precount] {
        let mut s = kind.build(&fresh, StrategyConfig::default()).unwrap();
        let want = relcount::learn::search::learn(&fresh, s.as_mut(), cfg).unwrap();
        assert_eq!(maintained.bn.nodes, want.bn.nodes, "{}", kind.name());
        assert_eq!(maintained.bn.parents, want.bn.parents, "{}", kind.name());
        assert_eq!(
            maintained.total_score.to_bits(),
            want.total_score.to_bits(),
            "{}: {} vs {}",
            kind.name(),
            maintained.total_score,
            want.total_score
        );
    }
}

/// From-scratch reference for one generation: rebuild its database
/// (fresh validation, fresh indexes) and serve every family through a
/// fresh ONDEMAND strategy.
fn reference_digests(
    gen: &Generation,
    fams: &[(Vec<RVar>, Vec<usize>)],
) -> Vec<u64> {
    let fresh = Database::new(
        gen.db().schema.clone(),
        gen.db().entities.clone(),
        gen.db().rels.clone(),
    )
    .unwrap();
    let mut s = StrategyKind::OnDemand.build(&fresh, StrategyConfig::default()).unwrap();
    fams.iter()
        .map(|(vars, ctx)| s.ct_for_family(vars, ctx).unwrap().digest())
        .collect()
}

/// The serving layer's snapshot-isolation contract, exercised live:
/// reader threads hammer a fixed family set while the writer publishes
/// churn generations concurrently.  Every answer is stamped with the
/// generation it came from and must be bit-identical to a from-scratch
/// strategy on **that exact generation's** database — an answer
/// blending generation N with N+1 (a torn read of a half-applied
/// batch) matches neither reference and fails.  Runs with 1 and 4
/// maintenance workers; the per-epoch generation digests must be
/// identical across the two, and the post-quiesce state bit-identical
/// to a from-scratch rebuild on the final database.
#[test]
fn concurrent_readers_match_exact_generations_never_blends() {
    const STEPS: u64 = 3;
    const READERS: usize = 3;
    let mut digests_by_workers: Vec<Vec<u64>> = Vec::new();

    for workers in [1usize, 4] {
        let db = seeded_db("uw");
        let fams: Vec<(Vec<RVar>, Vec<usize>)> =
            families_of(&db).into_iter().take(10).collect();
        let mut engine = ServeEngine::build(
            db,
            MaintainConfig { workers, ..Default::default() },
        )
        .unwrap();
        let store = engine.store();

        // every generation the writer publishes, in epoch order
        let mut gens: Vec<Arc<Generation>> = vec![store.load()];
        let answers: Mutex<Vec<(u64, usize, u64)>> = Mutex::new(Vec::new());
        let stop = AtomicBool::new(false);

        std::thread::scope(|scope| {
            for _ in 0..READERS {
                scope.spawn(|| {
                    while !stop.load(Ordering::Relaxed) {
                        // one load per pass: a pass never straddles a
                        // publish, like the server's micro-batches
                        let gen = store.load();
                        for (i, (vars, ctx)) in fams.iter().enumerate() {
                            let ct = gen.ct_for_family(vars, ctx).unwrap();
                            answers.lock().unwrap().push((gen.epoch, i, ct.digest()));
                        }
                    }
                });
            }
            for step in 0..STEPS {
                let batch = churn_batch(engine.db(), 0.3, 7_000 + step);
                engine.apply_publish(&batch).unwrap();
                gens.push(store.load());
                // let the readers serve from this generation for a bit
                std::thread::sleep(Duration::from_millis(15));
            }
            stop.store(true, Ordering::Relaxed);
        });

        // per-epoch truth: from-scratch rebuild of each generation's db
        assert_eq!(gens.len() as u64, STEPS + 1);
        let expected: Vec<Vec<u64>> =
            gens.iter().map(|g| reference_digests(g, &fams)).collect();
        let answers = answers.into_inner().unwrap();
        assert!(!answers.is_empty());
        for &(epoch, fam, digest) in &answers {
            assert_eq!(
                digest, expected[epoch as usize][fam],
                "workers={workers}: answer from epoch {epoch} family {fam} \
                 does not match that generation's from-scratch counts"
            );
        }

        // post-quiesce: the final state is bit-identical to a fresh
        // build on the (rebuilt) final database
        let last = gens.last().unwrap();
        let rebuilt = Database::new(
            last.db().schema.clone(),
            last.db().entities.clone(),
            last.db().rels.clone(),
        )
        .unwrap();
        let fresh = MaintainedCounts::build(
            rebuilt,
            MaintainConfig { workers, ..Default::default() },
        )
        .unwrap();
        assert_eq!(last.digest(), fresh.digest(), "workers={workers}");

        digests_by_workers.push(gens.iter().map(|g| g.digest()).collect());
    }

    // the generation sequence is bit-identical across worker counts
    assert_eq!(digests_by_workers[0], digests_by_workers[1]);
}

/// A mid-batch failure during concurrent serving: the bad batch is
/// reported on publish, the previous generation keeps serving (readers
/// never error, the epoch never advances), and the writer stays usable
/// for the next good batch.
#[test]
fn mid_batch_failure_keeps_previous_generation_serving() {
    let db = seeded_db("uw");
    let fams: Vec<(Vec<RVar>, Vec<usize>)> =
        families_of(&db).into_iter().take(6).collect();
    let mut engine = ServeEngine::build(db, MaintainConfig::default()).unwrap();
    let store = engine.store();

    let good = churn_batch(engine.db(), 0.2, 8_001);
    engine.apply_publish(&good).unwrap();
    let g1 = store.load();
    let before: Vec<u64> = fams
        .iter()
        .map(|(v, c)| g1.ct_for_family(v, c).unwrap().digest())
        .collect();

    // a batch whose first op mutates state and whose second op must
    // fail: a fresh entity is always insertable, a relationship index
    // of usize::MAX never resolves
    let bad = DeltaBatch::new(vec![
        DeltaOp::InsertEntity {
            et: 0,
            values: vec![0; engine.db().schema.entities[0].attrs.len()],
        },
        DeltaOp::DeleteLink { rel: usize::MAX, from: 0, to: 0 },
    ]);
    let stop = AtomicBool::new(false);
    std::thread::scope(|scope| {
        let reader = scope.spawn(|| {
            let mut served = 0u64;
            while !stop.load(Ordering::Relaxed) {
                let gen = store.load();
                for (vars, ctx) in &fams {
                    gen.ct_for_family(vars, ctx).unwrap(); // must never error
                    served += 1;
                }
            }
            served
        });
        assert!(engine.apply_publish(&bad).is_err(), "bad batch must fail");
        std::thread::sleep(Duration::from_millis(10));
        stop.store(true, Ordering::Relaxed);
        assert!(reader.join().unwrap() > 0);
    });

    // epoch unchanged, counts unchanged
    assert_eq!(store.epoch(), 1);
    let after: Vec<u64> = fams
        .iter()
        .map(|(v, c)| store.load().ct_for_family(v, c).unwrap().digest())
        .collect();
    assert_eq!(before, after);

    // the writer is not poisoned: the next good batch publishes
    let next = churn_batch(engine.db(), 0.2, 8_002);
    let (epoch, _) = engine.apply_publish(&next).unwrap();
    assert_eq!(epoch, 2);
    assert_eq!(store.epoch(), 2);
}

#[test]
fn delta_and_recount_modes_converge() {
    let db = seeded_db("hepatitis");
    let mut delta = MaintainedCounts::build(
        db.clone(),
        MaintainConfig { mode: MaintenanceMode::DeltaOnly, ..Default::default() },
    )
    .unwrap();
    let mut recount = MaintainedCounts::build(
        db,
        MaintainConfig { mode: MaintenanceMode::RecountOnly, ..Default::default() },
    )
    .unwrap();
    for step in 0..2u64 {
        let batch = churn_batch(delta.db(), 0.4, 4_000 + step);
        let dr = delta.apply(&batch).unwrap();
        let rr = recount.apply(&batch).unwrap();
        assert_eq!(delta.digest(), recount.digest(), "step {step}");
        assert_eq!(dr.points_recounted, 0, "step {step}");
        assert_eq!(rr.points_delta_maintained, 0, "step {step}");
    }
}
