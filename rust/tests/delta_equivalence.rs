//! The delta maintenance subsystem's differential contract (mirrors
//! strategy_equivalence.rs): after arbitrary seeded insert/delete
//! sequences, delta-maintained counts must be **bit-identical** to
//! from-scratch recounts — for all four strategies rebuilt on the
//! mutated data, sequentially and under `--workers 4`, including
//! learned structures and BDeu score bits.

use relcount::ct::cttable::CtTable;
use relcount::datagen::churn::churn_batch;
use relcount::datagen::{generator::generate, presets::preset};
use relcount::db::catalog::Database;
use relcount::delta::{MaintainConfig, MaintainedCounts, MaintenanceMode};
use relcount::lattice::Lattice;
use relcount::learn::search::SearchConfig;
use relcount::meta::rvar::RVar;
use relcount::strategies::traits::{CountingStrategy, StrategyConfig};
use relcount::strategies::StrategyKind;

/// Singleton and pair families over each lattice point's variable set
/// (the enumeration strategy_equivalence.rs uses, bounded for time).
fn families_of(db: &Database) -> Vec<(Vec<RVar>, Vec<usize>)> {
    let lattice = Lattice::build(&db.schema, 3).unwrap();
    let mut out = Vec::new();
    for p in &lattice.points {
        let vars = p.all_vars();
        for i in 0..vars.len() {
            out.push((vec![vars[i]], p.pops.clone()));
            for j in (i + 1)..vars.len() {
                out.push((vec![vars[i], vars[j]], p.pops.clone()));
            }
        }
    }
    out
}

fn assert_tables_equal(a: &CtTable, b: &CtTable, what: &str) {
    assert_eq!(a.n_rows(), b.n_rows(), "{what}: row count");
    for (vals, c) in b.iter_rows() {
        assert_eq!(a.get(&vals).unwrap(), c, "{what} at {vals:?}");
    }
}

/// Rebuild a fresh, from-scratch database from the maintained state's
/// current tables (fresh validation + fresh indexes — no maintained
/// structure is reused).
fn rebuild(m: &MaintainedCounts) -> Database {
    Database::new(
        m.db().schema.clone(),
        m.db().entities.clone(),
        m.db().rels.clone(),
    )
    .unwrap()
}

fn seeded_db(name: &str) -> Database {
    // 0.05 keeps the runs fast while giving batches enough link rows to
    // mix inserts, deletes and the occasional entity insert
    generate(&preset(name, 0.05, 42).unwrap()).unwrap()
}

#[test]
fn maintained_counts_match_all_four_strategies_after_churn() {
    for name in ["uw", "hepatitis"] {
        let db = seeded_db(name);
        let mut m = MaintainedCounts::build(db, MaintainConfig::default()).unwrap();
        for step in 0..3u64 {
            let batch = churn_batch(m.db(), 0.4, 1_000 + step);
            m.apply(&batch).unwrap();
            let fresh = rebuild(&m);
            let fams = families_of(&fresh);
            let mut strategies: Vec<Box<dyn CountingStrategy>> =
                StrategyKind::ALL_WITH_ADAPTIVE
                    .iter()
                    .map(|k| k.build(&fresh, StrategyConfig::default()).unwrap())
                    .collect();
            for (vars, ctx) in &fams {
                let maintained = m.ct_for_family(vars, ctx).unwrap();
                for s in strategies.iter_mut() {
                    let want = s.ct_for_family(vars, ctx).unwrap();
                    assert_tables_equal(
                        &maintained,
                        &want,
                        &format!("{name} step {step} {} {vars:?}", s.name()),
                    );
                }
            }
        }
    }
}

#[test]
fn partial_residency_plans_stay_exact_after_churn() {
    // hybrid-equivalent budget (positives only) and a half budget: serve
    // paths mix projections and fresh joins, counts must not care
    let db = seeded_db("uw");
    let probe =
        MaintainedCounts::build(db.clone(), MaintainConfig::default()).unwrap();
    let hb = probe.plan().hybrid_budget();
    for budget in [Some(hb), Some(hb / 2), Some(0)] {
        let cfg = MaintainConfig { mem_budget: budget, ..Default::default() };
        let mut m = MaintainedCounts::build(db.clone(), cfg).unwrap();
        let batch = churn_batch(m.db(), 0.4, 77);
        m.apply(&batch).unwrap();
        let fresh = rebuild(&m);
        let mut reference =
            StrategyKind::OnDemand.build(&fresh, StrategyConfig::default()).unwrap();
        for (vars, ctx) in families_of(&fresh) {
            let got = m.ct_for_family(&vars, &ctx).unwrap();
            let want = reference.ct_for_family(&vars, &ctx).unwrap();
            assert_tables_equal(&got, &want, &format!("budget {budget:?} {vars:?}"));
        }
    }
}

#[test]
fn four_workers_maintain_bit_identical_caches() {
    for name in ["uw", "hepatitis"] {
        let db = seeded_db(name);
        let mut seq = MaintainedCounts::build(
            db.clone(),
            MaintainConfig { workers: 1, ..Default::default() },
        )
        .unwrap();
        let mut par = MaintainedCounts::build(
            db,
            MaintainConfig { workers: 4, ..Default::default() },
        )
        .unwrap();
        assert_eq!(seq.digest(), par.digest(), "{name}: build");
        for step in 0..3u64 {
            let batch = churn_batch(seq.db(), 0.4, 2_000 + step);
            seq.apply(&batch).unwrap();
            par.apply(&batch).unwrap();
            assert_eq!(seq.digest(), par.digest(), "{name}: step {step}");
        }
        // and the served tables agree with a fresh strategy
        let fresh = rebuild(&par);
        let mut reference =
            StrategyKind::Hybrid.build(&fresh, StrategyConfig::default()).unwrap();
        for (vars, ctx) in families_of(&fresh).into_iter().take(40) {
            let got = par.ct_for_family(&vars, &ctx).unwrap();
            let want = reference.ct_for_family(&vars, &ctx).unwrap();
            assert_tables_equal(&got, &want, &format!("{name} w=4 {vars:?}"));
        }
    }
}

#[test]
fn learned_structures_and_bdeu_bits_survive_churn() {
    let db = seeded_db("uw");
    let mut m = MaintainedCounts::build(db, MaintainConfig::default()).unwrap();
    for step in 0..2u64 {
        let batch = churn_batch(m.db(), 0.3, 3_000 + step);
        m.apply(&batch).unwrap();
    }
    let cfg = SearchConfig::default();
    let maintained = m.learn(cfg).unwrap();

    let fresh = rebuild(&m);
    for kind in [StrategyKind::Hybrid, StrategyKind::Precount] {
        let mut s = kind.build(&fresh, StrategyConfig::default()).unwrap();
        let want = relcount::learn::search::learn(&fresh, s.as_mut(), cfg).unwrap();
        assert_eq!(maintained.bn.nodes, want.bn.nodes, "{}", kind.name());
        assert_eq!(maintained.bn.parents, want.bn.parents, "{}", kind.name());
        assert_eq!(
            maintained.total_score.to_bits(),
            want.total_score.to_bits(),
            "{}: {} vs {}",
            kind.name(),
            maintained.total_score,
            want.total_score
        );
    }
}

#[test]
fn delta_and_recount_modes_converge() {
    let db = seeded_db("hepatitis");
    let mut delta = MaintainedCounts::build(
        db.clone(),
        MaintainConfig { mode: MaintenanceMode::DeltaOnly, ..Default::default() },
    )
    .unwrap();
    let mut recount = MaintainedCounts::build(
        db,
        MaintainConfig { mode: MaintenanceMode::RecountOnly, ..Default::default() },
    )
    .unwrap();
    for step in 0..2u64 {
        let batch = churn_batch(delta.db(), 0.4, 4_000 + step);
        let dr = delta.apply(&batch).unwrap();
        let rr = recount.apply(&batch).unwrap();
        assert_eq!(delta.digest(), recount.digest(), "step {step}");
        assert_eq!(dr.points_recounted, 0, "step {step}");
        assert_eq!(rr.points_delta_maintained, 0, "step {step}");
    }
}
