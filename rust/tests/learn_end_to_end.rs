//! End-to-end structure learning over scaled benchmark presets: the full
//! pipeline (generate -> count -> score -> search) with every strategy,
//! checking model agreement, MP/N plausibility (paper Table 4) and the
//! timeout machinery.

use std::time::Duration;

use relcount::bench::driver::{run_strategy, Workload};
use relcount::datagen::{generator::generate, presets::preset};
use relcount::learn::search::{learn, SearchConfig};
use relcount::strategies::traits::StrategyConfig;
use relcount::strategies::StrategyKind;

#[test]
fn learn_on_scaled_presets_all_strategies_agree() {
    for name in ["uw", "mondial", "movielens"] {
        let cfg = preset(name, 0.05, 3).unwrap();
        let db = generate(&cfg).unwrap();
        let search = SearchConfig { max_ops_per_point: 60, ..Default::default() };
        let mut models = Vec::new();
        for kind in StrategyKind::ALL {
            let mut s = kind.build(&db, StrategyConfig::default()).unwrap();
            models.push(learn(&db, s.as_mut(), search).unwrap());
        }
        for m in &models[1..] {
            assert_eq!(m.bn.nodes, models[0].bn.nodes, "{name}");
            assert_eq!(m.bn.parents, models[0].bn.parents, "{name}");
        }
        let mpn = models[0].bn.mean_parents_per_node();
        // paper Table 4: MP/N between 0.5 and 3.4 across benchmarks
        assert!(mpn >= 0.0 && mpn <= 4.0, "{name} MP/N {mpn}");
    }
}

#[test]
fn learned_model_finds_injected_dependencies() {
    // the generator injects rel-attr <- endpoint-attr dependencies; the
    // search should recover edges (nonzero MP/N) at a usable scale
    let cfg = preset("uw", 0.3, 5).unwrap();
    let db = generate(&cfg).unwrap();
    let mut s = StrategyKind::Hybrid.build(&db, StrategyConfig::default()).unwrap();
    let model = learn(&db, s.as_mut(), SearchConfig::default()).unwrap();
    assert!(
        model.bn.n_edges() > 0,
        "expected edges:\n{}",
        model.bn.display(&db.schema)
    );
    assert!(model.total_score.is_finite());
    assert!(model.families_scored > 10);
}

#[test]
fn timeout_surfaces_as_timeout_row() {
    let cfg = preset("hepatitis", 0.2, 1).unwrap();
    let db = generate(&cfg).unwrap();
    let out = run_strategy(
        &db,
        "hepatitis",
        StrategyKind::OnDemand,
        Workload::Learn(SearchConfig::default()),
        Some(Duration::from_millis(1)),
    )
    .unwrap();
    assert!(out.row.timed_out);
    assert!(out.model.is_none());
}

#[test]
fn max_parents_respected_end_to_end() {
    let cfg = preset("mondial", 0.1, 2).unwrap();
    let db = generate(&cfg).unwrap();
    let search = SearchConfig { max_parents: 2, ..Default::default() };
    let mut s = StrategyKind::Hybrid.build(&db, StrategyConfig::default()).unwrap();
    let model = learn(&db, s.as_mut(), search).unwrap();
    for ps in &model.bn.parents {
        assert!(ps.len() <= 2);
    }
}

#[test]
fn report_metrics_are_consistent() {
    let cfg = preset("uw", 0.2, 4).unwrap();
    let db = generate(&cfg).unwrap();
    for kind in StrategyKind::ALL {
        let out = run_strategy(
            &db,
            "uw",
            kind,
            Workload::Learn(SearchConfig::default()),
            None,
        )
        .unwrap();
        let rep = &out.report;
        assert_eq!(rep.name, kind.name());
        assert!(rep.families_served > 0, "{}", kind.name());
        assert!(rep.peak_ct_bytes > 0, "{}", kind.name());
        assert!(rep.ct_rows_generated > 0, "{}", kind.name());
        // pre-counting strategies must not JOIN during search beyond the
        // lattice fill; ONDEMAND must JOIN plenty
        match kind {
            StrategyKind::OnDemand => {
                assert!(rep.join_stats.chain_queries > 10, "{}", kind.name())
            }
            _ => {
                // 7 entity/lattice queries at most for uw's 2-rel schema
                assert!(rep.join_stats.chain_queries <= 3, "{}", kind.name())
            }
        }
    }
}
