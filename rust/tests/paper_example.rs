//! Integration: the paper's running example (Table 3) reproduced through
//! every layer — fixture database, all three counting strategies, BDeu
//! scoring — with the exact counts printed in the paper.

use relcount::db::fixtures::{university_db, TABLE3_NEGATIVE, TABLE3_POSITIVE};
use relcount::learn::score::bdeu_from_ct;
use relcount::meta::rvar::RVar;
use relcount::strategies::traits::StrategyConfig;
use relcount::strategies::StrategyKind;

/// Table 3's variables: Capa(P,S), RA(P,S), Salary(P,S).
fn table3_vars() -> Vec<RVar> {
    vec![
        RVar::RelAttr { rel: 0, attr: 0 },
        RVar::RelInd { rel: 0 },
        RVar::RelAttr { rel: 0, attr: 1 },
    ]
}

#[test]
fn every_strategy_reproduces_table3() {
    let db = university_db();
    for kind in StrategyKind::ALL {
        let mut s = kind.build(&db, StrategyConfig::default()).unwrap();
        let ct = s.ct_for_family(&table3_vars(), &[0, 1]).unwrap();

        // the N/A row: 203 professor-student pairs without an RA tuple
        assert_eq!(
            ct.get(&[0, 0, 0]).unwrap(),
            TABLE3_NEGATIVE as i128,
            "{} N/A row",
            kind.name()
        );
        // all 9 positive rows; paper capability value c -> ct code c,
        // salary raw s -> ct code s + 1
        for &(capa, sal, count) in TABLE3_POSITIVE {
            assert_eq!(
                ct.get(&[capa, 1, sal + 1]).unwrap(),
                count as i128,
                "{} at capa={capa} salary={sal}",
                kind.name()
            );
        }
        // exactly the 10 rows of Table 3 (9 positive + 1 N/A)
        assert_eq!(ct.n_rows(), 10, "{}", kind.name());
        assert_eq!(ct.total().unwrap(), 228, "{}", kind.name());
    }
}

#[test]
fn table3_renders_like_the_paper() {
    let db = university_db();
    let mut s = StrategyKind::Hybrid.build(&db, StrategyConfig::default()).unwrap();
    let ct = s.ct_for_family(&table3_vars(), &[0, 1]).unwrap();
    let text = ct.render(&db.schema);
    assert!(text.contains("capability(P,S)"));
    assert!(text.contains("RA(P,S)"));
    assert!(text.contains("salary(P,S)"));
    assert!(text.contains("203"));
}

#[test]
fn salary_family_bdeu_is_finite_and_equal_across_strategies() {
    // the paper's example family: RA(P,S), Capa(P,S) -> Salary(P,S)
    let db = university_db();
    let child = RVar::RelAttr { rel: 0, attr: 1 };
    let mut scores = Vec::new();
    for kind in StrategyKind::ALL {
        let mut s = kind.build(&db, StrategyConfig::default()).unwrap();
        let ct = s.ct_for_family(&table3_vars(), &[0, 1]).unwrap();
        let score = bdeu_from_ct(&ct, &child, 1.0).unwrap();
        assert!(score.is_finite() && score < 0.0);
        scores.push(score);
    }
    assert!((scores[0] - scores[1]).abs() < 1e-12);
    assert!((scores[0] - scores[2]).abs() < 1e-12);
}

#[test]
fn example_count_from_the_paper_text() {
    // "the number of professor-student pairs such that the student is an
    // RA for the professor with a high capability of 4 and receives a
    // HIGH salary. In Table 3, this count equals 5."
    let db = university_db();
    let mut s = StrategyKind::Precount.build(&db, StrategyConfig::default()).unwrap();
    let ct = s.ct_for_family(&table3_vars(), &[0, 1]).unwrap();
    assert_eq!(ct.get(&[4, 1, 3]).unwrap(), 5);
}
