//! Crash-recovery and fault-injection contract of the persist layer.
//!
//! Three escalating proofs:
//!
//! 1. **Differential kill-at-every-boundary** — a reference run applies
//!    K seeded churn batches uninterrupted, recording the digest at
//!    every epoch.  Then for every batch boundary k the durable run is
//!    "killed" (the engine dropped cold: no shutdown snapshot, the WAL
//!    holding exactly k records) and recovered; epoch, digest, and
//!    served count tables must be bit-identical to the reference at k —
//!    for both index backends and at 1 and 4 workers.
//! 2. **Fault injection** — one flipped byte in any snapshot section
//!    (or the manifest) must surface as a typed [`Error::Persist`]
//!    naming that section, recovery must fall back to the previous
//!    valid snapshot + WAL replay, and a state that cannot be proven
//!    must never be served.
//! 3. **Real SIGKILL** — the `relcount` binary is killed (SIGKILL, no
//!    handlers) mid-churn-stream; a fresh process recovers the data
//!    dir and must land exactly on the last published epoch with the
//!    digest an uninterrupted in-process run produces at that epoch.

use std::path::PathBuf;

use relcount::datagen::churn::churn_batch;
use relcount::db::catalog::Database;
use relcount::db::fixtures::university_db;
use relcount::db::index::Backend;
use relcount::delta::{MaintainConfig, MaintainedCounts};
use relcount::error::Error;
use relcount::meta::rvar::RVar;
use relcount::persist::{verify_snapshot, write_snapshot, DataDir, WalWriter};
use relcount::serve::ServeEngine;

fn tmp(name: &str) -> PathBuf {
    let p = std::env::temp_dir()
        .join(format!("relcount-recovery-{}-{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&p);
    p
}

fn db_with(backend: Backend) -> Database {
    let mut db = university_db();
    db.set_backend(backend).unwrap();
    db
}

fn cfg_with(workers: usize) -> MaintainConfig {
    MaintainConfig { workers, ..Default::default() }
}

/// The deterministic churn sequence both runs share: batch for epoch e
/// is generated against the state the previous e-1 batches produced.
fn batch_seed(e: u64) -> u64 {
    0xD15C ^ e
}

fn family() -> (Vec<RVar>, Vec<usize>) {
    (
        vec![
            RVar::RelInd { rel: 0 },
            RVar::RelAttr { rel: 0, attr: 1 },
            RVar::EntityAttr { et: 1, attr: 0 },
        ],
        vec![0, 1],
    )
}

#[test]
fn kill_at_every_batch_boundary_recovers_bit_identically() {
    const K: u64 = 6;
    for backend in [Backend::Csr, Backend::Hash] {
        for workers in [1usize, 4] {
            // uninterrupted reference: digest at every epoch 0..=K
            let mut reference =
                MaintainedCounts::build(db_with(backend), cfg_with(workers)).unwrap();
            let mut ref_digests = vec![reference.digest()];
            let mut batches = Vec::new();
            for e in 1..=K {
                let b = churn_batch(reference.db(), 0.08, batch_seed(e));
                reference.apply(&b).unwrap();
                ref_digests.push(reference.digest());
                batches.push(b);
            }

            for kill_at in 0..=K {
                let root = tmp(&format!(
                    "bound-{}-{workers}-{kill_at}",
                    backend.name()
                ));
                let mut engine = ServeEngine::build(db_with(backend), cfg_with(workers))
                    .unwrap();
                engine
                    .attach_persistence(DataDir::open(&root).unwrap(), 2)
                    .unwrap();
                for b in &batches[..kill_at as usize] {
                    engine.apply_publish(b).unwrap();
                }
                let served_before = engine
                    .store()
                    .load()
                    .ct_for_family(&family().0, &family().1)
                    .unwrap();
                // crash: drop the engine with no shutdown snapshot —
                // on disk are the periodic snapshots plus kill_at WAL
                // records, exactly a SIGKILL at this boundary
                drop(engine);

                let dd = DataDir::open(&root).unwrap();
                let (recovered, epoch) = dd.recover(workers).unwrap();
                assert_eq!(epoch, kill_at, "{backend:?}/{workers}w");
                assert_eq!(
                    recovered.digest(),
                    ref_digests[kill_at as usize],
                    "digest after recovery at boundary {kill_at} ({backend:?}, {workers} workers)"
                );
                // and the recovered state *serves* the same answers
                let eng = ServeEngine::from_maintained_at(recovered, epoch).unwrap();
                let served_after = eng
                    .store()
                    .load()
                    .ct_for_family(&family().0, &family().1)
                    .unwrap();
                assert_eq!(
                    served_after.digest(),
                    served_before.digest(),
                    "served counts at boundary {kill_at}"
                );
                let _ = std::fs::remove_dir_all(&root);
            }
        }
    }
}

/// Snapshot saves prune WAL records at or below the oldest retained
/// snapshot's epoch.  Kill the engine at every batch boundary under the
/// most aggressive policy (snapshot + prune on every publish) and prove
/// the pruned log still carries snapshot-plus-suffix replay to the
/// uninterrupted reference — including the fallback past a damaged
/// newest snapshot, which is exactly the path pruning could starve.
#[test]
fn wal_pruning_never_breaks_snapshot_plus_suffix_replay() {
    const K: u64 = 5;
    for backend in [Backend::Csr, Backend::Hash] {
        let mut reference =
            MaintainedCounts::build(db_with(backend), cfg_with(1)).unwrap();
        let mut ref_digests = vec![reference.digest()];
        let mut batches = Vec::new();
        for e in 1..=K {
            let b = churn_batch(reference.db(), 0.08, batch_seed(e));
            reference.apply(&b).unwrap();
            ref_digests.push(reference.digest());
            batches.push(b);
        }

        for kill_at in 0..=K {
            let root = tmp(&format!("prune-{}-{kill_at}", backend.name()));
            let mut engine =
                ServeEngine::build(db_with(backend), cfg_with(1)).unwrap();
            engine
                .attach_persistence(DataDir::open(&root).unwrap(), 1)
                .unwrap();
            for b in &batches[..kill_at as usize] {
                engine.apply_publish(b).unwrap();
            }
            drop(engine);

            let dd = DataDir::open(&root).unwrap();
            // the prune actually ran: no record at or below the oldest
            // retained snapshot's epoch survives
            let cutoff = dd.wal_prune_cutoff().unwrap().unwrap();
            let recs = relcount::persist::read_records(&dd.wal_path()).unwrap();
            assert!(
                recs.iter().all(|r| r.epoch > cutoff),
                "records at or below cutoff {cutoff} survived: {:?} ({backend:?}, kill {kill_at})",
                recs.iter().map(|r| r.epoch).collect::<Vec<_>>()
            );

            let (recovered, epoch) = dd.recover(1).unwrap();
            assert_eq!(epoch, kill_at, "{backend:?} kill {kill_at}");
            assert_eq!(recovered.digest(), ref_digests[kill_at as usize]);

            // damage the newest snapshot: the older retained snapshot
            // plus the pruned suffix must reach the same state
            let epochs = dd.snapshot_epochs().unwrap();
            if epochs.len() >= 2 {
                let caches =
                    dd.snapshot_dir(*epochs.last().unwrap()).join("caches.bin");
                let mut bytes = std::fs::read(&caches).unwrap();
                let mid = bytes.len() / 2;
                bytes[mid] ^= 0x10;
                std::fs::write(&caches, &bytes).unwrap();
                let (fallback, fb_epoch) = dd.recover(1).unwrap();
                assert_eq!(fb_epoch, kill_at, "{backend:?} fallback {kill_at}");
                assert_eq!(fallback.digest(), ref_digests[kill_at as usize]);
            }
            let _ = std::fs::remove_dir_all(&root);
        }
    }
}

#[test]
fn torn_wal_tail_recovers_to_previous_boundary() {
    let root = tmp("torn-tail");
    let mut reference =
        MaintainedCounts::build(db_with(Backend::Csr), cfg_with(1)).unwrap();
    let mut engine =
        ServeEngine::build(db_with(Backend::Csr), cfg_with(1)).unwrap();
    engine.attach_persistence(DataDir::open(&root).unwrap(), 0).unwrap();
    let mut digests = vec![reference.digest()];
    for e in 1..=3u64 {
        let b = churn_batch(reference.db(), 0.08, batch_seed(e));
        reference.apply(&b).unwrap();
        digests.push(reference.digest());
        engine.apply_publish(&b).unwrap();
    }
    drop(engine);

    // the crash tore the last append mid-record: the suffix is gone
    let dd = DataDir::open(&root).unwrap();
    let wal = std::fs::read(dd.wal_path()).unwrap();
    std::fs::write(dd.wal_path(), &wal[..wal.len() - 7]).unwrap();

    let (recovered, epoch) = dd.recover(1).unwrap();
    assert_eq!(epoch, 2, "torn record 3 must be dropped, not replayed");
    assert_eq!(recovered.digest(), digests[2]);
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn one_flipped_byte_in_any_section_is_a_typed_error() {
    let root = tmp("flip");
    std::fs::create_dir_all(&root).unwrap();
    let mut m = MaintainedCounts::build(db_with(Backend::Csr), cfg_with(1)).unwrap();
    m.compact_indexes();
    write_snapshot(&root, &m, 7).unwrap();
    verify_snapshot(&root).unwrap(); // pristine: passes

    for file in ["db.bin", "csr.bin", "plan.bin", "caches.bin", "MANIFEST.json"] {
        let path = root.join(file);
        let pristine = std::fs::read(&path).unwrap();
        // flip one byte in the middle of the payload
        let mut bad = pristine.clone();
        let mid = bad.len() / 2;
        bad[mid] ^= 0x20;
        std::fs::write(&path, &bad).unwrap();

        let err = verify_snapshot(&root).expect_err(&format!("{file}: flip undetected"));
        match &err {
            Error::Persist { section, .. } => {
                let expect = file.trim_end_matches(".bin");
                // a manifest flip may corrupt the recorded digest
                // instead of the JSON itself; both sections are typed
                let ok = if file == "MANIFEST.json" {
                    section == "manifest" || section == "digest"
                } else {
                    section == expect
                };
                assert!(ok, "{file}: error named section {section:?}: {err}");
            }
            other => panic!("{file}: expected Error::Persist, got {other}"),
        }
        std::fs::write(&path, &pristine).unwrap();
        verify_snapshot(&root).unwrap(); // restored: passes again
    }
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn recovery_never_serves_an_unverified_snapshot() {
    let root = tmp("unverified");
    let dd = DataDir::open(&root).unwrap();
    let mut engine =
        ServeEngine::build(db_with(Backend::Csr), cfg_with(1)).unwrap();
    engine.attach_persistence(DataDir::open(&root).unwrap(), 1).unwrap();
    let mut expected = engine.digest();
    for e in 1..=2u64 {
        let b = churn_batch(engine.db(), 0.08, batch_seed(e));
        engine.apply_publish(&b).unwrap();
        expected = engine.digest();
    }
    drop(engine);
    assert_eq!(dd.snapshot_epochs().unwrap(), vec![1, 2]);

    // flip a byte in the newest snapshot's caches: recovery must fall
    // back to epoch 1 + WAL replay and still land on the epoch-2 state
    let caches = dd.snapshot_dir(2).join("caches.bin");
    let pristine = std::fs::read(&caches).unwrap();
    let mut bad = pristine.clone();
    let mid = bad.len() / 2;
    bad[mid] ^= 0x08;
    std::fs::write(&caches, &bad).unwrap();
    let (recovered, epoch) = dd.recover(1).unwrap();
    assert_eq!(epoch, 2);
    assert_eq!(recovered.digest(), expected);

    // with every snapshot damaged, recovery refuses outright — a wrong
    // count is never served
    let caches1 = dd.snapshot_dir(1).join("caches.bin");
    let mut bad1 = std::fs::read(&caches1).unwrap();
    let mid1 = bad1.len() / 2;
    bad1[mid1] ^= 0x08;
    std::fs::write(&caches1, &bad1).unwrap();
    let err = dd.recover(1).unwrap_err();
    match err {
        Error::Persist { section, .. } => assert_eq!(section, "caches"),
        other => panic!("expected Error::Persist, got {other}"),
    }
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn corrupt_wal_record_refuses_recovery() {
    let root = tmp("wal-corrupt");
    let dd = DataDir::open(&root).unwrap();
    let mut engine =
        ServeEngine::build(db_with(Backend::Csr), cfg_with(1)).unwrap();
    // every=0: only the initial snapshot exists; both batches are
    // WAL-only, so recovery must replay them
    engine.attach_persistence(DataDir::open(&root).unwrap(), 0).unwrap();
    for e in 1..=2u64 {
        let b = churn_batch(engine.db(), 0.08, batch_seed(e));
        engine.apply_publish(&b).unwrap();
    }
    drop(engine);

    // flip one byte inside the first record's payload (not the tail):
    // this is corruption, not a torn append
    let wal_path = dd.wal_path();
    let mut bytes = std::fs::read(&wal_path).unwrap();
    bytes[8 + 24 + 2] ^= 0x40; // magic(8) + header(24) + 2 into the JSON
    std::fs::write(&wal_path, &bytes).unwrap();

    let err = dd.recover(1).unwrap_err();
    match err {
        Error::Persist { section, msg } => {
            assert_eq!(section, "wal");
            assert!(msg.contains("record 0"), "{msg}");
        }
        other => panic!("expected Error::Persist, got {other}"),
    }
    let _ = std::fs::remove_dir_all(&root);
}

/// SIGKILL the real binary mid-stream, then prove a fresh process
/// recovers to exactly the last published generation.
#[test]
fn sigkill_mid_stream_recovers_to_last_published_generation() {
    use std::process::{Command, Stdio};

    let base = tmp("sigkill");
    std::fs::create_dir_all(&base).unwrap();
    let db_dir = base.join("db");
    let data_dir = base.join("data");
    relcount::db::loader::save(&university_db(), &db_dir).unwrap();

    // serve with a long seeded churn feed; stdin stays open so the
    // session never ends on its own
    let mut child = Command::new(env!("CARGO_BIN_EXE_relcount"))
        .args([
            "serve",
            "--db",
            db_dir.to_str().unwrap(),
            "--data-dir",
            data_dir.to_str().unwrap(),
            "--snapshot-every",
            "4",
            "--churn",
            "0.05",
            "--churn-steps",
            "500",
            "--delta-pause-ms",
            "10",
            "--seed",
            "42",
        ])
        .stdin(Stdio::piped())
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn relcount serve");

    // wait until at least a few batches are durable, then SIGKILL
    let dd = DataDir::open(&data_dir).unwrap();
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(60);
    loop {
        let n = relcount::persist::read_records(&dd.wal_path())
            .map(|r| r.len())
            .unwrap_or(0);
        if n >= 3 {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "server produced no WAL records within 60s"
        );
        std::thread::sleep(std::time::Duration::from_millis(20));
    }
    child.kill().expect("SIGKILL"); // SIGKILL on unix: no handlers run
    child.wait().unwrap();

    // recover in-process and diff against an uninterrupted run of the
    // same seeded feed (serve derives batch i's seed as
    // (seed ^ 0x5E47E) ^ (i + 1) against the current writer state)
    let (recovered, epoch) = dd.recover(1).unwrap();
    assert!(epoch >= 3, "killed after ≥3 durable records, epoch {epoch}");
    let mut reference =
        MaintainedCounts::build(db_with(Backend::Csr), cfg_with(1)).unwrap();
    for i in 0..epoch {
        let b = churn_batch(reference.db(), 0.05, (42u64 ^ 0x5E47E) ^ (i + 1));
        reference.apply(&b).unwrap();
    }
    assert_eq!(
        recovered.digest(),
        reference.digest(),
        "recovered state must be bit-identical to the uninterrupted run at epoch {epoch}"
    );

    // a restarted server must also accept the directory: simulate the
    // reopen path (torn-tail truncation + appendability)
    let mut w = WalWriter::open(&dd.wal_path()).unwrap();
    assert!(w.last_epoch() >= epoch);
    let next = churn_batch(recovered.db(), 0.05, 1);
    let mut cont = recovered;
    cont.apply(&next).unwrap();
    w.append(w.last_epoch() + 1, cont.digest(), &next).unwrap();
    let _ = std::fs::remove_dir_all(&base);
}
