//! Streaming-pipeline integration: replaying a generated database through
//! the bounded-channel ingestion pipeline reproduces it exactly, the
//! incremental counters match batch queries, and the rebuilt database
//! serves identical counts through HYBRID.

use relcount::datagen::{generator::generate, presets::preset};
use relcount::db::query::{groupby_entity, positive_chain_ct, JoinStats};
use relcount::meta::extract::{vars_for_chain, vars_for_entity};
use relcount::meta::rvar::RVar;
use relcount::pipeline::ingest::{ingest, IngestorConfig};
use relcount::pipeline::source::db_to_facts;
use relcount::strategies::traits::StrategyConfig;
use relcount::strategies::StrategyKind;

#[test]
fn replay_reproduces_database_exactly() {
    let cfg = preset("hepatitis", 0.05, 11).unwrap();
    let db = generate(&cfg).unwrap();
    let rep = ingest(
        db.schema.clone(),
        db_to_facts(&db),
        IngestorConfig { batch_size: 64, channel_batches: 3, incremental_counts: true },
    )
    .unwrap();
    assert_eq!(rep.facts, db.total_rows());
    assert_eq!(rep.db.total_rows(), db.total_rows());
    for (a, b) in db.entities.iter().zip(rep.db.entities.iter()) {
        assert_eq!(a.cols, b.cols);
    }
    for (a, b) in db.rels.iter().zip(rep.db.rels.iter()) {
        assert_eq!(a.from, b.from);
        assert_eq!(a.to, b.to);
        assert_eq!(a.cols, b.cols);
    }
}

#[test]
fn incremental_counts_match_batch_queries() {
    let cfg = preset("financial", 0.02, 12).unwrap();
    let db = generate(&cfg).unwrap();
    let rep = ingest(db.schema.clone(), db_to_facts(&db), IngestorConfig::default())
        .unwrap();
    let inc = rep.incremental.unwrap();
    for et in 0..db.schema.entities.len() {
        let vars = vars_for_entity(&db.schema, et);
        let batch = groupby_entity(&db, et, &vars).unwrap();
        assert_eq!(inc.entity_cts[et].n_rows(), batch.n_rows());
        for (v, c) in batch.iter_rows() {
            assert_eq!(inc.entity_cts[et].get(&v).unwrap(), c);
        }
    }
    for rel in 0..db.schema.relationships.len() {
        let vars = vars_for_chain(&db.schema, &[rel]);
        let mut stats = JoinStats::default();
        let batch = positive_chain_ct(&db, &[rel], &vars, &mut stats).unwrap();
        assert_eq!(inc.rel_cts[rel].n_rows(), batch.n_rows(), "rel {rel}");
        for (v, c) in batch.iter_rows() {
            assert_eq!(inc.rel_cts[rel].get(&v).unwrap(), c, "rel {rel} {v:?}");
        }
    }
}

#[test]
fn ingested_database_serves_identical_family_counts() {
    let cfg = preset("uw", 0.2, 13).unwrap();
    let db = generate(&cfg).unwrap();
    let rep = ingest(db.schema.clone(), db_to_facts(&db), IngestorConfig::default())
        .unwrap();
    let vars = vec![
        RVar::RelInd { rel: 0 },
        RVar::RelAttr { rel: 0, attr: 0 },
        RVar::EntityAttr { et: 1, attr: 0 },
    ];
    let mut s1 = StrategyKind::Hybrid.build(&db, StrategyConfig::default()).unwrap();
    let mut s2 = StrategyKind::Hybrid.build(&rep.db, StrategyConfig::default()).unwrap();
    let a = s1.ct_for_family(&vars, &[0, 1]).unwrap();
    let b = s2.ct_for_family(&vars, &[0, 1]).unwrap();
    assert_eq!(a.n_rows(), b.n_rows());
    for (v, c) in a.iter_rows() {
        assert_eq!(b.get(&v).unwrap(), c);
    }
}

#[test]
fn tiny_channel_exercises_backpressure() {
    let cfg = preset("mutagenesis", 0.05, 14).unwrap();
    let db = generate(&cfg).unwrap();
    let n = db.total_rows();
    let rep = ingest(
        db.schema.clone(),
        db_to_facts(&db),
        // 1-batch channel with per-fact batches: maximal contention
        IngestorConfig { batch_size: 1, channel_batches: 1, incremental_counts: false },
    )
    .unwrap();
    assert_eq!(rep.facts, n);
    assert_eq!(rep.batches, n);
    assert!(rep.incremental.is_none());
}

#[test]
fn malformed_streams_error_cleanly() {
    use relcount::pipeline::source::Fact;
    let cfg = preset("uw", 0.05, 15).unwrap();
    let db = generate(&cfg).unwrap();
    // a link to a nonexistent entity id
    let mut facts = db_to_facts(&db);
    facts.push(Fact::Link { rel: 0, from: 999_999, to: 0, values: vec![0, 0] });
    let r = ingest(db.schema.clone(), facts, IngestorConfig::default());
    assert!(r.is_err());
}
