//! Property-based tests over randomly generated schemas and databases
//! (in-tree generator; the proptest crate is unavailable offline).
//! Each property runs against many seeded random cases; failures print
//! the seed for deterministic reproduction.

use relcount::ct::cross::outer;
use relcount::ct::dense::{DenseLayout, D_PAD, E_PAD, K_REL};
use relcount::ct::mobius::{brute_force_complete, mobius_complete};
use relcount::ct::project::project;
use relcount::db::catalog::Database;
use relcount::db::index::pair_key;
use relcount::db::query::{positive_chain_ct, DirectSource, JoinStats};
use relcount::db::schema::{Attribute, EntityType, RelationshipType, Schema};
use relcount::delta::{DeltaBatch, DeltaOp, MaintainConfig, MaintainedCounts};
use relcount::estimate::{EstimatorConfig, JoinSampler};
use relcount::lattice::Lattice;
use relcount::meta::rvar::RVar;
use relcount::strategies::traits::{CountingStrategy, StrategyConfig};
use relcount::strategies::StrategyKind;
use relcount::util::fxhash::FxHashSet;
use relcount::util::json::Json;
use relcount::util::rng::Rng;

/// A random small schema: 2-3 entity types with 0-2 attrs, 1-3 distinct
/// relationships over distinct endpoint pairs.
fn random_schema(rng: &mut Rng) -> Schema {
    let n_ets = 2 + rng.gen_range(2) as usize;
    let entities: Vec<EntityType> = (0..n_ets)
        .map(|i| EntityType {
            name: format!("E{i}"),
            attrs: (0..rng.gen_range(3))
                .map(|a| Attribute::new(format!("a{a}"), 2 + rng.gen_u32(2)))
                .collect(),
        })
        .collect();
    // candidate endpoint pairs
    let mut pairs = Vec::new();
    for i in 0..n_ets {
        for j in 0..n_ets {
            if i != j {
                pairs.push((i, j));
            }
        }
    }
    rng.shuffle(&mut pairs);
    let n_rels = 1 + rng.gen_range(pairs.len().min(3) as u64) as usize;
    let relationships: Vec<RelationshipType> = pairs[..n_rels]
        .iter()
        .enumerate()
        .map(|(k, &(f, t))| RelationshipType {
            name: format!("R{k}"),
            from: f,
            to: t,
            attrs: (0..rng.gen_range(2))
                .map(|a| Attribute::new(format!("w{a}"), 2 + rng.gen_u32(2)))
                .collect(),
        })
        .collect();
    Schema::new(entities, relationships).unwrap()
}

/// A random small database over a random schema.
fn random_db(rng: &mut Rng) -> Database {
    let schema = random_schema(rng);
    let mut db = Database::empty(schema.clone());
    for (et, e) in schema.entities.iter().enumerate() {
        let n = 1 + rng.gen_range(6) as u32;
        for _ in 0..n {
            let row: Vec<u32> = e.attrs.iter().map(|a| rng.gen_u32(a.card)).collect();
            db.entities[et].push(&row).unwrap();
        }
    }
    for (rt, r) in schema.relationships.iter().enumerate() {
        let nf = db.entities[r.from].len();
        let nt = db.entities[r.to].len();
        for f in 0..nf {
            for t in 0..nt {
                if rng.gen_bool(0.35) {
                    let row: Vec<u32> =
                        r.attrs.iter().map(|a| rng.gen_u32(a.card)).collect();
                    db.rels[rt].push(f, t, &row).unwrap();
                }
            }
        }
    }
    db.build_indexes().unwrap();
    db
}

/// A random family over the schema (vars + covering context).
fn random_family(rng: &mut Rng, db: &Database) -> (Vec<RVar>, Vec<usize>) {
    let schema = &db.schema;
    let mut pool: Vec<RVar> = Vec::new();
    for (et, e) in schema.entities.iter().enumerate() {
        for attr in 0..e.attrs.len() {
            pool.push(RVar::EntityAttr { et, attr });
        }
    }
    for (rel, r) in schema.relationships.iter().enumerate() {
        pool.push(RVar::RelInd { rel });
        for attr in 0..r.attrs.len() {
            pool.push(RVar::RelAttr { rel, attr });
        }
    }
    rng.shuffle(&mut pool);
    let n = 1 + rng.gen_range(3.min(pool.len() as u64));
    let vars: Vec<RVar> = pool[..n as usize].to_vec();
    // context = all populations (covers everything)
    let ctx: Vec<usize> = (0..schema.entities.len()).collect();
    (vars, ctx)
}

const CASES: u64 = 60;

#[test]
fn prop_mobius_equals_brute_force() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed);
        let db = random_db(&mut rng);
        let (vars, ctx) = random_family(&mut rng, &db);
        let mut src = DirectSource::new(&db);
        let fast = mobius_complete(&mut src, &vars, &ctx)
            .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        let brute = brute_force_complete(&db, &vars, &ctx).unwrap();
        assert_eq!(fast.n_rows(), brute.n_rows(), "seed {seed}");
        for (v, c) in brute.iter_rows() {
            assert_eq!(fast.get(&v).unwrap(), c, "seed {seed} at {v:?}");
        }
    }
}

#[test]
fn prop_strategies_are_interchangeable() {
    for seed in 100..100 + CASES {
        let mut rng = Rng::new(seed);
        let db = random_db(&mut rng);
        let (vars, ctx) = random_family(&mut rng, &db);
        let mut tables = Vec::new();
        for kind in StrategyKind::ALL {
            let mut s = kind.build(&db, StrategyConfig::default()).unwrap();
            tables.push(s.ct_for_family(&vars, &ctx).unwrap_or_else(|e| {
                panic!("seed {seed} {kind:?}: {e}")
            }));
        }
        for t in &tables[1..] {
            assert_eq!(t.n_rows(), tables[0].n_rows(), "seed {seed}");
            for (v, c) in tables[0].iter_rows() {
                assert_eq!(t.get(&v).unwrap(), c, "seed {seed} at {v:?}");
            }
        }
    }
}

#[test]
fn prop_total_mass_is_population_product() {
    for seed in 200..200 + CASES {
        let mut rng = Rng::new(seed);
        let db = random_db(&mut rng);
        let (vars, ctx) = random_family(&mut rng, &db);
        let mut src = DirectSource::new(&db);
        let ct = mobius_complete(&mut src, &vars, &ctx).unwrap();
        assert_eq!(
            ct.total().unwrap() as u64,
            db.population_product(&ctx),
            "seed {seed}"
        );
        ct.assert_counts_nonnegative().unwrap();
    }
}

#[test]
fn prop_projection_commutes_with_mobius() {
    // projecting an attribute column out of the complete table equals
    // completing the family without that column
    for seed in 300..300 + CASES {
        let mut rng = Rng::new(seed);
        let db = random_db(&mut rng);
        let (vars, ctx) = random_family(&mut rng, &db);
        if vars.len() < 2 {
            continue;
        }
        let keep: Vec<RVar> = vars[..vars.len() - 1].to_vec();
        // only drop attribute columns: dropping an *indicator* changes the
        // Möbius axes for rel attrs that remain, which is a different op
        if vars[vars.len() - 1].is_indicator() {
            continue;
        }
        let mut src = DirectSource::new(&db);
        let full = mobius_complete(&mut src, &vars, &ctx).unwrap();
        let projected = project(&full, &keep).unwrap();
        let mut src2 = DirectSource::new(&db);
        let direct = mobius_complete(&mut src2, &keep, &ctx).unwrap();
        assert_eq!(projected.n_rows(), direct.n_rows(), "seed {seed}");
        for (v, c) in direct.iter_rows() {
            assert_eq!(projected.get(&v).unwrap(), c, "seed {seed} {v:?}");
        }
    }
}

#[test]
fn prop_dense_roundtrip_when_fits() {
    for seed in 400..400 + CASES {
        let mut rng = Rng::new(seed);
        let db = random_db(&mut rng);
        let (vars, ctx) = random_family(&mut rng, &db);
        let layout = match DenseLayout::fits(&db.schema, &vars, D_PAD, K_REL, E_PAD) {
            Some(l) => l,
            None => continue,
        };
        let ct = brute_force_complete(&db, &vars, &ctx).unwrap();
        let dense = layout.pack(&ct).unwrap();
        let back = layout.unpack(&db.schema, &dense).unwrap();
        assert_eq!(back.n_rows(), ct.n_rows(), "seed {seed}");
        for (v, c) in ct.iter_rows() {
            assert_eq!(back.get(&v).unwrap(), c, "seed {seed} {v:?}");
        }
    }
}

#[test]
fn prop_outer_product_total() {
    for seed in 500..500 + CASES {
        let mut rng = Rng::new(seed);
        let db = random_db(&mut rng);
        if db.schema.entities.len() < 2
            || db.schema.entities[0].attrs.is_empty()
            || db.schema.entities[1].attrs.is_empty()
        {
            continue;
        }
        let a = relcount::db::query::groupby_entity(
            &db,
            0,
            &[RVar::EntityAttr { et: 0, attr: 0 }],
        )
        .unwrap();
        let b = relcount::db::query::groupby_entity(
            &db,
            1,
            &[RVar::EntityAttr { et: 1, attr: 0 }],
        )
        .unwrap();
        let o = outer(&a, &b).unwrap();
        assert_eq!(
            o.total().unwrap(),
            a.total().unwrap() * b.total().unwrap(),
            "seed {seed}"
        );
    }
}

/// True join-chain cardinality, by actually executing the join.
fn true_chain_cardinality(db: &Database, chain: &[usize]) -> f64 {
    let mut stats = JoinStats::default();
    positive_chain_ct(db, chain, &[], &mut stats).unwrap().total().unwrap() as f64
}

#[test]
fn prop_estimator_exact_on_exhaustive_sampling() {
    // The random databases are tiny, so the default exhaustive limit
    // kicks in: every chain estimate must be *exact*.
    for seed in 1000..1000 + CASES {
        let mut rng = Rng::new(seed);
        let db = random_db(&mut rng);
        let lattice = Lattice::build(&db.schema, 3).unwrap();
        let sampler = JoinSampler::new(&db, EstimatorConfig::default());
        for p in &lattice.points {
            let e = sampler.chain_cardinality(&p.rels).unwrap();
            assert!(e.exact, "seed {seed} chain {:?}: cap {}", p.rels, e.cap);
            let truth = true_chain_cardinality(&db, &p.rels);
            assert_eq!(e.value, truth, "seed {seed} chain {:?}", p.rels);
            assert_eq!(e.lo, e.hi, "seed {seed}");
            assert!(truth <= e.cap, "seed {seed}");
        }
    }
}

#[test]
fn prop_estimates_within_declared_bounds() {
    // Force the sampling path (exhaustive enumeration off): the declared
    // interval [lo, hi] must cover the true cardinality, and the
    // deterministic cap must bound it.
    let cfg = EstimatorConfig { exhaustive_limit: 0, walks: 2048, ..Default::default() };
    for seed in 1100..1100 + CASES {
        let mut rng = Rng::new(seed);
        let db = random_db(&mut rng);
        let lattice = Lattice::build(&db.schema, 3).unwrap();
        let sampler = JoinSampler::new(&db, cfg);
        for p in &lattice.points {
            let e = sampler.chain_cardinality(&p.rels).unwrap();
            let truth = true_chain_cardinality(&db, &p.rels);
            assert!(truth <= e.cap, "seed {seed} {:?}: truth {truth} > cap {}", p.rels, e.cap);
            assert!(
                e.lo <= truth && truth <= e.hi,
                "seed {seed} {:?}: [{}, {}] misses {truth} (est {}, {} walks)",
                p.rels,
                e.lo,
                e.hi,
                e.value,
                e.walks
            );
            // single-relationship chains are always exact
            if p.rels.len() == 1 {
                assert_eq!(e.value, truth, "seed {seed}");
            }
        }
    }
}

#[test]
fn prop_adaptive_interchangeable_at_random_budgets() {
    // ADAPTIVE must serve the same tables as the fixed strategies at
    // *any* budget, not just the reference points.
    for seed in 1200..1200 + 30 {
        let mut rng = Rng::new(seed);
        let db = random_db(&mut rng);
        let (vars, ctx) = random_family(&mut rng, &db);
        let budget = match rng.gen_range(4) {
            0 => Some(0),
            1 => Some(rng.gen_range(1 << 14)),
            2 => Some(rng.gen_range(1 << 20)),
            _ => None,
        };
        let mut reference =
            StrategyKind::OnDemand.build(&db, StrategyConfig::default()).unwrap();
        let expect = reference.ct_for_family(&vars, &ctx).unwrap();
        let scfg = StrategyConfig { mem_budget: budget, ..Default::default() };
        let mut adaptive = StrategyKind::Adaptive.build(&db, scfg).unwrap();
        let got = adaptive.ct_for_family(&vars, &ctx).unwrap_or_else(|e| {
            panic!("seed {seed} budget {budget:?}: {e}")
        });
        assert_eq!(got.n_rows(), expect.n_rows(), "seed {seed} budget {budget:?}");
        for (v, c) in expect.iter_rows() {
            assert_eq!(got.get(&v).unwrap(), c, "seed {seed} budget {budget:?} {v:?}");
        }
    }
}

/// A random batch of link ops over distinct `(rel, from, to)` pairs:
/// deletes of existing tuples and inserts of absent pairs, so any
/// application order reaches the same final state.
fn random_link_batch(rng: &mut Rng, db: &Database, max_ops: usize) -> DeltaBatch {
    let mut ops = Vec::new();
    let mut touched: FxHashSet<(usize, u64)> = FxHashSet::default();
    for _ in 0..max_ops {
        if db.rels.is_empty() {
            break;
        }
        let rel = rng.gen_range(db.rels.len() as u64) as usize;
        let r = &db.schema.relationships[rel];
        let (nf, nt) = (db.entities[r.from].len(), db.entities[r.to].len());
        if nf == 0 || nt == 0 {
            continue;
        }
        let from = rng.gen_u32(nf);
        let to = rng.gen_u32(nt);
        if !touched.insert((rel, pair_key(from, to))) {
            continue; // keep pairs distinct within the batch
        }
        if db.index(rel).unwrap().lookup(from, to).is_some() {
            ops.push(DeltaOp::DeleteLink { rel, from, to });
        } else {
            let values: Vec<u32> =
                r.attrs.iter().map(|a| rng.gen_u32(a.card)).collect();
            ops.push(DeltaOp::InsertLink { rel, from, to, values });
        }
    }
    DeltaBatch::new(ops)
}

const DELTA_CASES: u64 = 20;

#[test]
fn prop_delta_insert_then_delete_is_noop() {
    // applying a batch and then its inverse restores every resident
    // ct-table bit-for-bit (the maintained digest covers them all)
    for seed in 1300..1300 + DELTA_CASES {
        let mut rng = Rng::new(seed);
        let db = random_db(&mut rng);
        let mut m = MaintainedCounts::build(db, MaintainConfig::default())
            .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        let d0 = m.digest();
        let batch = random_link_batch(&mut rng, m.db(), 6);
        if batch.is_empty() {
            continue;
        }
        // build the exact inverse against the post-batch state
        let inverse: Vec<DeltaOp> = batch
            .ops
            .iter()
            .rev()
            .map(|op| match op {
                DeltaOp::InsertLink { rel, from, to, .. } => {
                    DeltaOp::DeleteLink { rel: *rel, from: *from, to: *to }
                }
                DeltaOp::DeleteLink { rel, from, to } => {
                    let t = m.db().index(*rel).unwrap().lookup(*from, *to).unwrap();
                    let values: Vec<u32> = (0..m.db().rels[*rel].cols.len())
                        .map(|a| m.db().rels[*rel].value(a, t))
                        .collect();
                    DeltaOp::InsertLink { rel: *rel, from: *from, to: *to, values }
                }
                DeltaOp::InsertEntity { .. } => unreachable!("link batch"),
            })
            .collect();
        m.apply(&batch).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        m.apply(&DeltaBatch::new(inverse))
            .unwrap_or_else(|e| panic!("seed {seed} (inverse): {e}"));
        assert_eq!(m.digest(), d0, "seed {seed}: caches did not round-trip");
    }
}

#[test]
fn prop_delta_application_is_order_independent() {
    // within a batch over distinct pairs, op order must not matter for
    // the maintained caches
    for seed in 1400..1400 + DELTA_CASES {
        let mut rng = Rng::new(seed);
        let db = random_db(&mut rng);
        let batch = random_link_batch(&mut rng, &db, 6);
        if batch.len() < 2 {
            continue;
        }
        let mut shuffled = batch.ops.clone();
        rng.shuffle(&mut shuffled);
        let mut a = MaintainedCounts::build(db.clone(), MaintainConfig::default())
            .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        let mut b = MaintainedCounts::build(db, MaintainConfig::default()).unwrap();
        a.apply(&batch).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        b.apply(&DeltaBatch::new(shuffled))
            .unwrap_or_else(|e| panic!("seed {seed} (shuffled): {e}"));
        assert_eq!(a.digest(), b.digest(), "seed {seed}: order changed the caches");
    }
}

#[test]
fn prop_delta_counts_never_go_negative() {
    // random churn (incl. entity inserts) must keep every resident table
    // non-negative and every complete total at the population product —
    // apply() verifies both internally (MaintainConfig::verify is on by
    // default), so a violation fails loudly here; re-check a family
    // against brute force for good measure
    for seed in 1500..1500 + DELTA_CASES {
        let mut rng = Rng::new(seed);
        let db = random_db(&mut rng);
        let mut m = MaintainedCounts::build(db, MaintainConfig::default())
            .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        for step in 0..2 {
            let mut batch = random_link_batch(&mut rng, m.db(), 5);
            let et = rng.gen_range(m.db().schema.entities.len() as u64) as usize;
            let values: Vec<u32> = m.db().schema.entities[et]
                .attrs
                .iter()
                .map(|a| rng.gen_u32(a.card))
                .collect();
            batch.ops.push(DeltaOp::InsertEntity { et, values });
            m.apply(&batch)
                .unwrap_or_else(|e| panic!("seed {seed} step {step}: {e}"));
        }
        let (vars, ctx) = random_family(&mut rng, m.db());
        let got = m
            .ct_for_family(&vars, &ctx)
            .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        got.assert_counts_nonnegative().unwrap();
        let want = brute_force_complete(m.db(), &vars, &ctx).unwrap();
        assert_eq!(got.n_rows(), want.n_rows(), "seed {seed}");
        for (v, c) in want.iter_rows() {
            assert_eq!(got.get(&v).unwrap(), c, "seed {seed} {v:?}");
        }
    }
}

/// A random sequence of mutations driven through the `Database`
/// mutators (exercising the CSR overlay: inserts, tombstones, swap
/// relabels, entity grows).
fn random_churn(rng: &mut Rng, db: &mut Database, ops: usize) {
    for _ in 0..ops {
        if db.rels.is_empty() {
            return;
        }
        if rng.gen_bool(0.1) {
            let et = rng.gen_range(db.schema.entities.len() as u64) as usize;
            let values: Vec<u32> = db.schema.entities[et]
                .attrs
                .iter()
                .map(|a| rng.gen_u32(a.card))
                .collect();
            db.insert_entity(et, &values).unwrap();
            continue;
        }
        let rel = rng.gen_range(db.rels.len() as u64) as usize;
        let r = &db.schema.relationships[rel];
        let (nf, nt) = (db.entities[r.from].len(), db.entities[r.to].len());
        if nf == 0 || nt == 0 {
            continue;
        }
        let from = rng.gen_u32(nf);
        let to = rng.gen_u32(nt);
        if db.index(rel).unwrap().lookup(from, to).is_some() {
            db.delete_link(rel, from, to).unwrap();
        } else {
            let values: Vec<u32> = r.attrs.iter().map(|a| rng.gen_u32(a.card)).collect();
            db.insert_link(rel, from, to, &values).unwrap();
        }
    }
}

#[test]
fn prop_csr_neighbor_runs_sorted_and_consistent() {
    // every CSR run is strictly ascending, degree-consistent, and its
    // (nbr, tid) entries point back at the owning table rows
    for seed in 1600..1600 + CASES {
        let mut rng = Rng::new(seed);
        let db = random_db(&mut rng);
        for rel in 0..db.rels.len() {
            let ix = db.index(rel).unwrap();
            let t = &db.rels[rel];
            let r = &db.schema.relationships[rel];
            let mut covered = 0usize;
            for f in 0..db.entities[r.from].len() {
                let run = ix.sorted_nbrs_from(f).expect("clean CSR row");
                assert!(
                    run.windows(2).all(|w| w[0] < w[1]),
                    "seed {seed} rel {rel} row {f} not strictly ascending"
                );
                assert_eq!(run.len(), ix.degree_from(f), "seed {seed}");
                for (k, &nbr) in run.iter().enumerate() {
                    let (n2, tid) =
                        ix.nth_from(t, f, k).expect("k < degree");
                    assert_eq!(n2, nbr, "seed {seed}");
                    assert_eq!(t.from[tid as usize], f, "seed {seed}");
                    assert_eq!(t.to[tid as usize], nbr, "seed {seed}");
                    assert_eq!(ix.lookup(f, nbr), Some(tid), "seed {seed}");
                }
                covered += run.len();
            }
            assert_eq!(covered, t.len() as usize, "seed {seed} rel {rel}");
        }
    }
}

#[test]
fn prop_csr_overlay_then_compact_matches_rebuild() {
    // random churn through the mutators (overlay path), then: reads
    // must match a from-scratch rebuild both *before* and *after*
    // compaction, and compaction must reproduce the rebuild's base
    // arrays exactly
    for seed in 1650..1650 + DELTA_CASES {
        let mut rng = Rng::new(seed);
        let mut db = random_db(&mut rng);
        random_churn(&mut rng, &mut db, 25);
        let fresh =
            Database::new(db.schema.clone(), db.entities.clone(), db.rels.clone())
                .unwrap();
        let check_reads = |db: &Database| {
            for rel in 0..db.rels.len() {
                let r = &db.schema.relationships[rel];
                let (a, b) = (db.index(rel).unwrap(), fresh.index(rel).unwrap());
                assert_eq!(a.len(), b.len(), "seed {seed} rel {rel}");
                assert_eq!(a.max_degree(), b.max_degree(), "seed {seed}");
                for f in 0..db.entities[r.from].len() {
                    assert_eq!(a.degree_from(f), b.degree_from(f), "seed {seed}");
                    for o in 0..db.entities[r.to].len() {
                        assert_eq!(a.lookup(f, o), b.lookup(f, o), "seed {seed}");
                    }
                }
            }
        };
        check_reads(&db); // overlay still pending
        db.compact_indexes();
        assert_eq!(db.index_overlay_len(), 0, "seed {seed}");
        check_reads(&db); // compacted
        for rel in 0..db.rels.len() {
            let r = &db.schema.relationships[rel];
            let (a, b) = (db.index(rel).unwrap(), fresh.index(rel).unwrap());
            for f in 0..db.entities[r.from].len() {
                assert_eq!(
                    a.sorted_nbrs_from(f),
                    b.sorted_nbrs_from(f),
                    "seed {seed} rel {rel} row {f}"
                );
            }
            for o in 0..db.entities[r.to].len() {
                assert_eq!(
                    a.sorted_nbrs_to(o),
                    b.sorted_nbrs_to(o),
                    "seed {seed} rel {rel} rev row {o}"
                );
            }
        }
    }
}

#[test]
fn prop_backends_count_identically_under_both_kernels() {
    // identical ct-tables *and* identical JoinStats accounting on every
    // lattice point, after random churn, for csr x ccsr x hash under
    // both join kernels (the `exp compress` gate's property)
    use relcount::db::index::Backend;
    use relcount::db::wcoj::JoinKernel;
    for seed in 1700..1700 + DELTA_CASES {
        let mut rng = Rng::new(seed);
        let mut csr = random_db(&mut rng);
        random_churn(&mut rng, &mut csr, 15);
        let mut ccsr = csr.clone();
        ccsr.set_backend(Backend::Ccsr).unwrap();
        let mut hash = csr.clone();
        hash.set_backend(Backend::Hash).unwrap();
        let lattice = Lattice::build(&csr.schema, 3).unwrap();
        for kernel in [JoinKernel::Chain, JoinKernel::Wcoj] {
            csr.set_kernel(kernel);
            ccsr.set_kernel(kernel);
            hash.set_kernel(kernel);
            for p in &lattice.points {
                let mut s1 = JoinStats::default();
                let mut s2 = JoinStats::default();
                let mut s3 = JoinStats::default();
                let a = positive_chain_ct(&csr, &p.rels, &p.attr_vars, &mut s1)
                    .unwrap_or_else(|e| panic!("seed {seed} csr: {e}"));
                let b = positive_chain_ct(&ccsr, &p.rels, &p.attr_vars, &mut s2)
                    .unwrap_or_else(|e| panic!("seed {seed} ccsr: {e}"));
                let c = positive_chain_ct(&hash, &p.rels, &p.attr_vars, &mut s3)
                    .unwrap_or_else(|e| panic!("seed {seed} hash: {e}"));
                assert_eq!(s1, s2, "seed {seed} {kernel:?} {:?}: stats", p.rels);
                assert_eq!(s2, s3, "seed {seed} {kernel:?} {:?}: stats", p.rels);
                assert_eq!(a.digest(), b.digest(), "seed {seed} {kernel:?} {:?}", p.rels);
                assert_eq!(b.digest(), c.digest(), "seed {seed} {kernel:?} {:?}", p.rels);
                for (v, w) in a.iter_rows() {
                    assert_eq!(b.get(&v).unwrap(), w, "seed {seed} {:?} {v:?}", p.rels);
                }
            }
        }
    }
}

#[test]
fn prop_ccsr_overlay_then_compact_matches_rebuild() {
    // the ccsr overlay path under random churn (applied op-for-op in
    // lockstep with a csr twin): reads must match csr both while the
    // overlay is pending and after compaction, and the compacted blocks
    // must decode to exactly the runs a from-scratch ccsr rebuild packs
    use relcount::db::index::Backend;
    for seed in 2100..2100 + DELTA_CASES {
        let mut rng = Rng::new(seed);
        let mut csr = random_db(&mut rng);
        let mut ccsr = csr.clone();
        ccsr.set_backend(Backend::Ccsr).unwrap();
        // identical mutation sequence on both: decisions depend only on
        // the rng and the (identical) visible state
        for _ in 0..25 {
            let rel = rng.gen_range(csr.rels.len() as u64) as usize;
            let r = csr.schema.relationships[rel].clone();
            let (nf, nt) = (csr.entities[r.from].len(), csr.entities[r.to].len());
            let from = rng.gen_u32(nf);
            let to = rng.gen_u32(nt);
            if csr.index(rel).unwrap().lookup(from, to).is_some() {
                csr.delete_link(rel, from, to).unwrap();
                ccsr.delete_link(rel, from, to).unwrap();
            } else {
                let values: Vec<u32> =
                    r.attrs.iter().map(|a| rng.gen_u32(a.card)).collect();
                csr.insert_link(rel, from, to, &values).unwrap();
                ccsr.insert_link(rel, from, to, &values).unwrap();
            }
        }
        let check_reads = |csr: &Database, ccsr: &Database| {
            for rel in 0..csr.rels.len() {
                let r = &csr.schema.relationships[rel];
                let (a, b) = (csr.index(rel).unwrap(), ccsr.index(rel).unwrap());
                assert_eq!(a.len(), b.len(), "seed {seed} rel {rel}");
                assert_eq!(a.max_degree(), b.max_degree(), "seed {seed}");
                for f in 0..csr.entities[r.from].len() {
                    assert_eq!(a.degree_from(f), b.degree_from(f), "seed {seed}");
                    for o in 0..csr.entities[r.to].len() {
                        assert_eq!(a.lookup(f, o), b.lookup(f, o), "seed {seed}");
                    }
                }
                for o in 0..csr.entities[r.to].len() {
                    assert_eq!(a.degree_to(o), b.degree_to(o), "seed {seed}");
                }
            }
        };
        check_reads(&csr, &ccsr); // overlays still pending
        csr.compact_indexes();
        ccsr.compact_indexes();
        assert_eq!(ccsr.index_overlay_len(), 0, "seed {seed}");
        check_reads(&csr, &ccsr); // compacted
        // a from-scratch ccsr rebuild packs the same runs the churned
        // index decodes to
        let mut fresh = Database::new(
            ccsr.schema.clone(),
            ccsr.entities.clone(),
            ccsr.rels.clone(),
        )
        .unwrap();
        fresh.set_backend(Backend::Ccsr).unwrap();
        for rel in 0..ccsr.rels.len() {
            let r = &ccsr.schema.relationships[rel];
            let (a, b) = (ccsr.index(rel).unwrap(), fresh.index(rel).unwrap());
            for f in 0..ccsr.entities[r.from].len() {
                let (ra, rb) = (
                    a.neighbor_run_from(f).expect("compacted ccsr row"),
                    b.neighbor_run_from(f).expect("fresh ccsr row"),
                );
                assert_eq!(ra.len(), rb.len(), "seed {seed} rel {rel} row {f}");
                for k in 0..ra.len() {
                    assert_eq!(
                        ra.pair_at(k),
                        rb.pair_at(k),
                        "seed {seed} rel {rel} row {f} entry {k}"
                    );
                }
            }
        }
    }
}

#[test]
fn prop_sampler_draw_order_is_backend_invariant() {
    // the canonical-order walk contract: the k-th neighbor (and its
    // tuple id) drawn by nth_from/nth_to is identical on every backend,
    // so seeded estimator walks visit the same tuples everywhere
    use relcount::db::index::Backend;
    for seed in 2200..2200 + DELTA_CASES {
        let mut rng = Rng::new(seed);
        let mut csr = random_db(&mut rng);
        random_churn(&mut rng, &mut csr, 12);
        let mut ccsr = csr.clone();
        ccsr.set_backend(Backend::Ccsr).unwrap();
        let mut hash = csr.clone();
        hash.set_backend(Backend::Hash).unwrap();
        for rel in 0..csr.rels.len() {
            let r = &csr.schema.relationships[rel];
            let t = &csr.rels[rel];
            let a = csr.index(rel).unwrap();
            let b = ccsr.index(rel).unwrap();
            let c = hash.index(rel).unwrap();
            for f in 0..csr.entities[r.from].len() {
                for k in 0..a.degree_from(f) {
                    let want = a.nth_from(t, f, k);
                    assert_eq!(want, b.nth_from(t, f, k), "seed {seed} rel {rel}");
                    assert_eq!(want, c.nth_from(t, f, k), "seed {seed} rel {rel}");
                }
            }
            for o in 0..csr.entities[r.to].len() {
                for k in 0..a.degree_to(o) {
                    let want = a.nth_to(t, o, k);
                    assert_eq!(want, b.nth_to(t, o, k), "seed {seed} rel {rel}");
                    assert_eq!(want, c.nth_to(t, o, k), "seed {seed} rel {rel}");
                }
            }
        }
    }
}

#[test]
fn prop_backend_cache_digests_match_across_strategies() {
    // the CI gate's property: every strategy's resident-cache digest is
    // identical under --backend csr, --backend ccsr and --backend hash
    use relcount::db::index::Backend;
    for seed in 1750..1750 + DELTA_CASES {
        let mut rng = Rng::new(seed);
        let csr = random_db(&mut rng);
        let mut others = Vec::new();
        for backend in [Backend::Ccsr, Backend::Hash] {
            let mut db = csr.clone();
            db.set_backend(backend).unwrap();
            others.push(db);
        }
        let (vars, ctx) = random_family(&mut rng, &csr);
        for kind in StrategyKind::ALL_WITH_ADAPTIVE {
            let mut a = kind.build(&csr, StrategyConfig::default()).unwrap();
            a.prepare().unwrap_or_else(|e| panic!("seed {seed} {kind:?}: {e}"));
            let prep_digest = a.cache_digest();
            let ta = a.ct_for_family(&vars, &ctx).unwrap();
            let serve_digest = a.cache_digest();
            for other in &others {
                let name = other.backend().name();
                let mut b = kind.build(other, StrategyConfig::default()).unwrap();
                b.prepare().unwrap();
                assert_eq!(
                    prep_digest,
                    b.cache_digest(),
                    "seed {seed} {kind:?} {name}: prepare digests diverged"
                );
                let tb = b.ct_for_family(&vars, &ctx).unwrap();
                assert_eq!(ta.digest(), tb.digest(), "seed {seed} {kind:?} {name}");
                assert_eq!(
                    serve_digest,
                    b.cache_digest(),
                    "seed {seed} {kind:?} {name}: serving digests diverged"
                );
            }
        }
    }
}

#[test]
fn prop_family_cache_returns_identical_tables() {
    for seed in 600..620 {
        let mut rng = Rng::new(seed);
        let db = random_db(&mut rng);
        let (vars, ctx) = random_family(&mut rng, &db);
        let mut s = StrategyKind::Hybrid.build(&db, StrategyConfig::default()).unwrap();
        let first = s.ct_for_family(&vars, &ctx).unwrap();
        let second = s.ct_for_family(&vars, &ctx).unwrap(); // cache hit
        assert_eq!(first.n_rows(), second.n_rows());
        for (v, c) in first.iter_rows() {
            assert_eq!(second.get(&v).unwrap(), c, "seed {seed}");
        }
        assert!(s.report().cache_hits >= 1);
    }
}

#[test]
fn prop_json_roundtrip_fuzz() {
    fn random_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.gen_range(4) } else { rng.gen_range(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.gen_bool(0.5)),
            2 => Json::Num((rng.gen_range(2_000_001) as f64) - 1_000_000.0),
            3 => {
                let n = rng.gen_range(12);
                Json::Str((0..n).map(|_| (32 + rng.gen_u32(90)) as u8 as char).collect())
            }
            4 => Json::Arr((0..rng.gen_range(4)).map(|_| random_json(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.gen_range(4))
                    .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    for seed in 700..700 + 2 * CASES {
        let mut rng = Rng::new(seed);
        let j = random_json(&mut rng, 3);
        let s = j.dump();
        let back = Json::parse(&s).unwrap_or_else(|e| panic!("seed {seed}: {e}\n{s}"));
        assert_eq!(back, j, "seed {seed}");
    }
}

#[test]
fn prop_schema_json_roundtrip() {
    for seed in 900..900 + CASES {
        let mut rng = Rng::new(seed);
        let schema = random_schema(&mut rng);
        let j = schema.to_json().dump();
        let back = Schema::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(back, schema, "seed {seed}");
    }
}

// --------------------------------------------------------------- estimate

#[test]
fn prop_incremental_summary_equals_rebuild_after_churn() {
    // the summary tier is maintained op-by-op inside MaintainedCounts;
    // after any sequence of random churn batches it must equal a
    // from-scratch rebuild over the post-churn database
    use relcount::estimate::SummaryStats;
    for seed in 1900..1900 + DELTA_CASES {
        let mut rng = Rng::new(seed);
        let db = random_db(&mut rng);
        let mut m = MaintainedCounts::build(db, MaintainConfig::default())
            .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        assert_eq!(*m.summary(), SummaryStats::build(m.db()), "seed {seed} fresh");
        for step in 0..3 {
            let mut batch = random_link_batch(&mut rng, m.db(), 6);
            if rng.gen_bool(0.5) {
                let et = rng.gen_range(m.db().schema.entities.len() as u64) as usize;
                let values: Vec<u32> = m.db().schema.entities[et]
                    .attrs
                    .iter()
                    .map(|a| rng.gen_u32(a.card))
                    .collect();
                batch.ops.push(DeltaOp::InsertEntity { et, values });
            }
            if batch.is_empty() {
                continue;
            }
            m.apply(&batch)
                .unwrap_or_else(|e| panic!("seed {seed} step {step}: {e}"));
            assert_eq!(
                *m.summary(),
                SummaryStats::build(m.db()),
                "seed {seed} step {step}: incremental summary drifted from rebuild"
            );
        }
    }
}

#[test]
fn prop_summary_bound_zero_is_bit_identical_to_sampler_only() {
    // the planner invariant: at summary_bound 0 the summary tier is
    // never consulted, so estimates and plans are bit-identical to the
    // sampler-only path — on both index backends
    use relcount::db::index::Backend;
    use relcount::estimate::{CountPlan, SummaryStats};
    for seed in 2000..2000 + DELTA_CASES {
        let mut rng = Rng::new(seed);
        let mut db = random_db(&mut rng);
        random_churn(&mut rng, &mut db, 10);
        let lattice = Lattice::build(&db.schema, 3).unwrap();
        let mut levels_by_backend = Vec::new();
        for backend in [Backend::Csr, Backend::Hash] {
            db.set_backend(backend).unwrap();
            let summary = SummaryStats::build(&db);
            // force the sampling path, where a consulted summary *would*
            // change the result — bound 0 must keep it untouched
            let cfg = EstimatorConfig {
                exhaustive_limit: 0,
                walks: 64,
                ..Default::default()
            };
            let sampler = JoinSampler::new(&db, cfg);
            for p in &lattice.points {
                let a = sampler.chain_cardinality(&p.rels).unwrap();
                let b = sampler
                    .chain_cardinality_with(&p.rels, Some(&summary))
                    .unwrap();
                assert_eq!(
                    a.value.to_bits(),
                    b.value.to_bits(),
                    "seed {seed} {backend:?} {:?}: bound-0 summary changed the estimate",
                    p.rels
                );
                assert_eq!(a.lo.to_bits(), b.lo.to_bits(), "seed {seed}");
                assert_eq!(a.hi.to_bits(), b.hi.to_bits(), "seed {seed}");
                assert_eq!(a.exact, b.exact, "seed {seed}");
                assert_eq!(a.walks, b.walks, "seed {seed}");
            }
            // and the whole plan is bit-identical whether or not the
            // tier field is spelled out
            let plain = CountPlan::build(
                &db,
                &lattice,
                EstimatorConfig::default(),
                Some(20_000),
            )
            .unwrap();
            let tiered = CountPlan::build(
                &db,
                &lattice,
                EstimatorConfig { summary_bound: 0.0, ..Default::default() },
                Some(20_000),
            )
            .unwrap();
            assert_eq!(plain.levels, tiered.levels, "seed {seed} {backend:?}");
            assert_eq!(plain.marginals, tiered.marginals, "seed {seed}");
            assert_eq!(
                plain.est_spent_bytes, tiered.est_spent_bytes,
                "seed {seed} {backend:?}"
            );
            levels_by_backend.push(plain.levels);
        }
        assert_eq!(
            levels_by_backend[0], levels_by_backend[1],
            "seed {seed}: plan diverged across backends"
        );
    }
}

// ---------------------------------------------------------------- persist

#[test]
fn prop_snapshot_save_load_roundtrip_is_identity() {
    // save -> load must reproduce the exact maintained state (digest,
    // epoch, serviceability), and re-saving the loaded state must emit
    // byte-identical section files — the encoding is canonical, so any
    // state difference would show up as a byte difference
    use relcount::db::index::Backend;
    use relcount::persist::{load_snapshot, write_snapshot};

    for seed in 1700..1700 + 12u64 {
        let mut rng = Rng::new(seed);
        let mut db = random_db(&mut rng);
        let backend = match seed % 3 {
            0 => Backend::Csr,
            1 => Backend::Hash,
            _ => Backend::Ccsr,
        };
        db.set_backend(backend).unwrap();
        let mem_budget = match rng.gen_range(3) {
            0 => None,          // everything resident
            1 => Some(0),       // nothing resident: empty caches section
            _ => Some(1 + rng.gen_u32(1 << 20) as u64),
        };
        let cfg = MaintainConfig { mem_budget, ..Default::default() };
        let mut m = MaintainedCounts::build(db, cfg)
            .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        let batch = random_link_batch(&mut rng, m.db(), 5);
        if !batch.is_empty() {
            m.apply(&batch).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        }
        m.compact_indexes();

        let base = std::env::temp_dir()
            .join(format!("relcount-prop-snap-{}-{seed}", std::process::id()));
        let (d1, d2) = (base.join("a"), base.join("b"));
        let _ = std::fs::remove_dir_all(&base);
        std::fs::create_dir_all(&d1).unwrap();
        std::fs::create_dir_all(&d2).unwrap();

        write_snapshot(&d1, &m, 3).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        let state = load_snapshot(&d1).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        assert_eq!(state.epoch, 3, "seed {seed}");
        assert_eq!(state.cache_digest, m.digest(), "seed {seed}");
        let mut reloaded = state
            .into_maintained(0)
            .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        assert_eq!(reloaded.digest(), m.digest(), "seed {seed}");

        write_snapshot(&d2, &reloaded, 3).unwrap();
        let files =
            ["MANIFEST.json", "db.bin", "csr.bin", "ccsr.bin", "plan.bin", "caches.bin"];
        for f in files {
            let a = d1.join(f);
            if !a.exists() {
                // the index section is backend-specific: csr.bin only on
                // the CSR backend, ccsr.bin only on CCSR
                let owner = match f {
                    "csr.bin" => Some(Backend::Csr),
                    "ccsr.bin" => Some(Backend::Ccsr),
                    _ => None,
                };
                assert_ne!(Some(backend), owner, "seed {seed}: {f} missing");
                assert!(owner.is_some(), "seed {seed}: {f} missing");
                continue;
            }
            assert_eq!(
                std::fs::read(&a).unwrap(),
                std::fs::read(d2.join(f)).unwrap(),
                "seed {seed}: re-saved {f} is not byte-identical"
            );
        }

        // the reloaded state is live: further batches maintain in step
        let b2 = random_link_batch(&mut rng, m.db(), 4);
        if !b2.is_empty() {
            m.apply(&b2).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
            reloaded
                .apply(&b2)
                .unwrap_or_else(|e| panic!("seed {seed} (reloaded): {e}"));
            assert_eq!(m.digest(), reloaded.digest(), "seed {seed}: diverged");
        }
        let _ = std::fs::remove_dir_all(&base);
    }
}

#[test]
fn prop_wal_replay_equals_in_memory_application() {
    // append -> replay must reproduce the live application batch by
    // batch (each record's recorded digest matches the replayed state)
    use relcount::persist::{read_records, WalWriter};

    for seed in 1800..1800 + 12u64 {
        let mut rng = Rng::new(seed);
        let db = random_db(&mut rng);
        let mut live = MaintainedCounts::build(db.clone(), MaintainConfig::default())
            .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        let path = std::env::temp_dir()
            .join(format!("relcount-prop-wal-{}-{seed}.log", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let mut w = WalWriter::open(&path).unwrap();
        let mut epoch = 0u64;
        for i in 0..4 {
            let b = random_link_batch(&mut rng, live.db(), 5);
            if b.is_empty() {
                continue;
            }
            live.apply(&b).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
            epoch += 1;
            w.append(epoch, live.digest(), &b).unwrap();
            if i == 1 {
                // reopen mid-stream: append must continue seamlessly
                drop(w);
                w = WalWriter::open(&path).unwrap();
                assert_eq!(w.last_epoch(), epoch, "seed {seed}");
            }
        }
        drop(w);

        let mut replay = MaintainedCounts::build(db, MaintainConfig::default())
            .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        for rec in read_records(&path).unwrap() {
            replay
                .apply(&rec.batch)
                .unwrap_or_else(|e| panic!("seed {seed} epoch {}: {e}", rec.epoch));
            assert_eq!(
                replay.digest(),
                rec.digest,
                "seed {seed}: replay diverged at epoch {}",
                rec.epoch
            );
        }
        assert_eq!(replay.digest(), live.digest(), "seed {seed}");
        let _ = std::fs::remove_file(&path);
    }
}
