//! Cross-layer numerics: the Rust implementations vs the AOT-compiled
//! XLA artifacts (Pallas kernels lowered by `python/compile/aot.py`).
//!
//! Requires `make artifacts`; tests skip (with a notice) if the artifact
//! directory is absent so `cargo test` stays runnable in isolation.

use std::path::PathBuf;

use relcount::ct::dense::{mobius_dense, DenseLayout, Q_PAD, R_PAD};
use relcount::ct::mobius::brute_force_complete;
use relcount::db::fixtures::university_db;
use relcount::learn::score::{bdeu_from_ct, ln_gamma};
use relcount::meta::rvar::RVar;
use relcount::runtime::batcher::{FamilyCounts, ScoreBatcher, ScoreService};
use relcount::runtime::client::Runtime;
use relcount::util::rng::Rng;

fn artifact_dir() -> Option<PathBuf> {
    let dir = relcount::runtime::default_artifact_dir();
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        None
    }
}

fn family_vars() -> Vec<RVar> {
    vec![
        RVar::RelInd { rel: 0 },
        RVar::RelAttr { rel: 0, attr: 1 },
        RVar::EntityAttr { et: 1, attr: 0 },
    ]
}

#[test]
fn mobius_artifact_matches_rust_dense_and_sparse() {
    let Some(dir) = artifact_dir() else { return };
    let rt = Runtime::load(&dir).unwrap();
    let spec = rt.manifest.artifact("mobius").unwrap();
    let d = spec.meta_dim("d_pad").unwrap();
    let k = spec.meta_dim("k_rel").unwrap();
    let e = spec.meta_dim("e_pad").unwrap();

    let db = university_db();
    let vars = family_vars();
    let layout = DenseLayout::fits(&db.schema, &vars, d, k, e).unwrap();

    // build the unconstrained g tensor from the complete table by inverse
    // butterfly (zeta), as in the unit test for mobius_dense
    let complete = brute_force_complete(&db, &vars, &[0, 1]).unwrap();
    let mut g = layout.pack(&complete).unwrap();
    for axis in 0..k {
        let outer = d.pow(axis as u32);
        let inner = d.pow((k - axis - 1) as u32) * e;
        for o in 0..outer {
            let base = o * d * inner;
            for v in 1..d {
                for j in 0..inner {
                    let add = g[base + v * inner + j];
                    g[base + j] += add;
                }
            }
        }
    }

    // XLA path
    let xla_out = rt.mobius(&g).unwrap();
    // Rust dense path
    let mut rust_out = g.clone();
    mobius_dense(&mut rust_out, d, k, e);

    assert_eq!(xla_out.len(), rust_out.len());
    for (i, (a, b)) in xla_out.iter().zip(&rust_out).enumerate() {
        assert_eq!(a, b, "cell {i}");
    }
    // and the sparse truth
    let back = layout.unpack(&db.schema, &xla_out).unwrap();
    assert_eq!(back.n_rows(), complete.n_rows());
    for (v, c) in complete.iter_rows() {
        assert_eq!(back.get(&v).unwrap(), c, "{v:?}");
    }
}

#[test]
fn bdeu_artifact_matches_rust_scorer() {
    let Some(dir) = artifact_dir() else { return };
    let rt = Runtime::load(&dir).unwrap();
    let mut batcher = ScoreBatcher::new(&rt).unwrap();

    let db = university_db();
    let vars = family_vars();
    let ct = brute_force_complete(&db, &vars, &[0, 1]).unwrap();
    let child = RVar::EntityAttr { et: 1, attr: 0 };
    let n_prime = 1.0;
    let rust_score = bdeu_from_ct(&ct, &child, n_prime).unwrap();

    // pack (q, r) matrix: parents = RA, salary; child = intelligence
    let child_pos = ct.var_pos(&child).unwrap();
    let q: usize = ct
        .dims
        .iter()
        .enumerate()
        .filter(|&(i, _)| i != child_pos)
        .map(|(_, &d)| d as usize)
        .product();
    let r = ct.dims[child_pos] as usize;
    let mut counts = vec![0.0; q * r];
    for (vals, c) in ct.iter_rows() {
        let mut j = 0usize;
        for (i, v) in vals.iter().enumerate() {
            if i != child_pos {
                j = j * ct.dims[i] as usize + *v as usize;
            }
        }
        counts[j * r + vals[child_pos] as usize] += c as f64;
    }
    let xla_score = batcher
        .score_all(&[FamilyCounts { counts, q, r, n_prime }])
        .unwrap()[0];
    assert!(
        (xla_score - rust_score).abs() < 1e-9 * rust_score.abs().max(1.0),
        "xla {xla_score} vs rust {rust_score}"
    );
}

#[test]
fn bdeu_batch_random_families_match() {
    let Some(dir) = artifact_dir() else { return };
    let rt = Runtime::load(&dir).unwrap();
    let mut batcher = ScoreBatcher::new(&rt).unwrap();
    let mut rng = Rng::new(17);
    // more families than one batch to exercise chunking
    let n = batcher.batch_size() + 13;
    let mut reqs = Vec::new();
    let mut want = Vec::new();
    for _ in 0..n {
        let q = 1 + rng.gen_range(12) as usize;
        let r = 2 + rng.gen_range(5) as usize;
        let counts: Vec<f64> =
            (0..q * r).map(|_| rng.gen_range(40) as f64).collect();
        let n_prime = 1.0 + rng.gen_range(4) as f64;
        // scalar reference
        let ar = n_prime / q as f64;
        let ac = n_prime / (q * r) as f64;
        let mut s = 0.0;
        for j in 0..q {
            let row = &counts[j * r..(j + 1) * r];
            let nij: f64 = row.iter().sum();
            if nij > 0.0 {
                s += ln_gamma(ar) - ln_gamma(nij + ar);
                for &c in row {
                    if c > 0.0 {
                        s += ln_gamma(c + ac) - ln_gamma(ac);
                    }
                }
            }
        }
        want.push(s);
        reqs.push(FamilyCounts { counts, q, r, n_prime });
    }
    let got = batcher.score_all(&reqs).unwrap();
    assert_eq!(got.len(), want.len());
    assert!(batcher.dispatches >= 2, "chunking exercised");
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        assert!((g - w).abs() < 1e-9 * w.abs().max(1.0), "family {i}: {g} vs {w}");
    }
}

#[test]
fn fused_family_score_matches_composition() {
    let Some(dir) = artifact_dir() else { return };
    let rt = Runtime::load(&dir).unwrap();
    let spec = rt.manifest.artifact("family_score").unwrap();
    let d = spec.meta_dim("d_pad").unwrap();
    let k = spec.meta_dim("k_rel").unwrap();
    let e = spec.meta_dim("e_pad").unwrap();

    let db = university_db();
    let vars = family_vars();
    let layout = DenseLayout::fits(&db.schema, &vars, d, k, e).unwrap();
    let complete = brute_force_complete(&db, &vars, &[0, 1]).unwrap();
    let mut g = layout.pack(&complete).unwrap();
    for axis in 0..k {
        let outer = d.pow(axis as u32);
        let inner = d.pow((k - axis - 1) as u32) * e;
        for o in 0..outer {
            let base = o * d * inner;
            for v in 1..d {
                for j in 0..inner {
                    let add = g[base + v * inner + j];
                    g[base + j] += add;
                }
            }
        }
    }
    // family: parents = {RA, salary} (cols 0,1), child = intelligence (2)
    let seg = layout.seg_map(&db.schema, &[0, 1], 2, Q_PAD, R_PAD).unwrap();
    let q = 2 * 4;
    let r = 3;
    let n_prime = 1.0;
    let (score, complete_dense) = rt
        .family_score(&g, &seg, n_prime / q as f64, n_prime / (q * r) as f64)
        .unwrap();
    let child = RVar::EntityAttr { et: 1, attr: 0 };
    let want = bdeu_from_ct(&complete, &child, n_prime).unwrap();
    assert!((score - want).abs() < 1e-9 * want.abs().max(1.0), "{score} vs {want}");
    // fused path also returns the complete tensor
    let back = layout.unpack(&db.schema, &complete_dense).unwrap();
    for (v, c) in complete.iter_rows() {
        assert_eq!(back.get(&v).unwrap(), c);
    }
}

#[test]
fn xla_backend_end_to_end_learning() {
    // End-to-end: structure learning with the batched XLA scorer.  The
    // greedy search may break exact score ties differently than the Rust
    // scorer (lgamma implementations differ at ~1e-12), so we do not
    // demand identical structures; we demand (a) the XLA path is really
    // exercised, (b) every family of BOTH learned models scores
    // identically (1e-9) under both backends, and (c) both models are
    // local optima of comparable quality.
    let Some(dir) = artifact_dir() else { return };
    use relcount::learn::backend::{bdeu_matrix, XlaBackend};
    use relcount::learn::score::{bdeu_from_ct, family_matrix};
    use relcount::learn::search::{learn, learn_with_backend, SearchConfig};
    use relcount::strategies::traits::StrategyConfig;
    use relcount::strategies::StrategyKind;

    let db = university_db();
    let cfg = SearchConfig::default();

    let mut s1 = StrategyKind::Hybrid.build(&db, StrategyConfig::default()).unwrap();
    let rust_model = learn(&db, s1.as_mut(), cfg).unwrap();

    let mut s2 = StrategyKind::Hybrid.build(&db, StrategyConfig::default()).unwrap();
    let mut backend = XlaBackend::load(&dir).unwrap();
    let xla_model = learn_with_backend(&db, s2.as_mut(), &mut backend, cfg).unwrap();

    assert!(backend.xla_scored > 0, "XLA path must actually be exercised");
    assert!(backend.dispatches > 0);
    assert_eq!(xla_model.bn.nodes, rust_model.bn.nodes);

    // per-family score parity across backends, for both learned models
    let mut s3 = StrategyKind::Hybrid.build(&db, StrategyConfig::default()).unwrap();
    for model in [&rust_model, &xla_model] {
        for fam in model.bn.families() {
            let rels = fam.rels();
            let ctx = if rels.is_empty() {
                fam.populations(&db.schema)
            } else {
                db.schema.populations_of(&rels)
            };
            let ct = s3.ct_for_family(&fam.vars(), &ctx).unwrap();
            let sparse = bdeu_from_ct(&ct, &fam.child, cfg.n_prime).unwrap();
            if let Some(req) = family_matrix(&ct, &fam.child, cfg.n_prime).unwrap() {
                let dense = bdeu_matrix(&req).unwrap();
                assert!(
                    (dense - sparse).abs() < 1e-9 * sparse.abs().max(1.0),
                    "{}",
                    fam.display(&db.schema)
                );
            }
        }
    }
    // comparable quality (same landscape, possibly different local optimum)
    let rel_gap = (xla_model.total_score - rust_model.total_score).abs()
        / rust_model.total_score.abs();
    assert!(rel_gap < 0.01, "score gap {rel_gap}");
    eprintln!(
        "xla backend: {} families over {} dispatches ({} scalar fallbacks)",
        backend.xla_scored, backend.dispatches, backend.fallback_scored
    );
}

#[test]
fn score_service_concurrent_producers() {
    let Some(dir) = artifact_dir() else { return };
    let service = ScoreService::spawn(dir).unwrap();
    let mut handles = Vec::new();
    for t in 0..4 {
        let sender = service.sender();
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(t);
            let mut out = Vec::new();
            for _ in 0..25 {
                let q = 1 + rng.gen_range(6) as usize;
                let r = 2 + rng.gen_range(4) as usize;
                let counts: Vec<f64> =
                    (0..q * r).map(|_| rng.gen_range(20) as f64).collect();
                let fc = FamilyCounts { counts: counts.clone(), q, r, n_prime: 1.0 };
                let score = sender.score(fc).unwrap();
                // sequential scalar reference
                let ar = 1.0 / q as f64;
                let ac = 1.0 / (q * r) as f64;
                let mut want = 0.0;
                for j in 0..q {
                    let row = &counts[j * r..(j + 1) * r];
                    let nij: f64 = row.iter().sum();
                    if nij > 0.0 {
                        want += ln_gamma(ar) - ln_gamma(nij + ar);
                        for &c in row {
                            if c > 0.0 {
                                want += ln_gamma(c + ac) - ln_gamma(ac);
                            }
                        }
                    }
                }
                out.push((score, want));
            }
            out
        }));
    }
    for h in handles {
        for (got, want) in h.join().unwrap() {
            assert!((got - want).abs() < 1e-9 * want.abs().max(1.0));
        }
    }
}
