//! The scale-out serving contract, end to end through the public API:
//! a shard + router topology on localhost must answer every count and
//! score request **byte-identically** to single-process `relcount
//! serve` — for every index backend and join kernel — a dead shard must
//! surface as a typed `route error` (never a wrong count), a restarted
//! shard must be picked back up transparently, and a replication
//! follower must publish the leader's epochs bit-identically (with
//! digest tampering detected, not absorbed).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Duration;

use relcount::datagen::{
    churn::churn_batch, generator::generate, presets::preset,
};
use relcount::db::catalog::Database;
use relcount::db::index::Backend;
use relcount::db::wcoj::JoinKernel;
use relcount::delta::{DeltaOp, MaintainConfig};
use relcount::serve::replicate::{follow, ReplRecord};
use relcount::serve::{
    enumerate_requests, run_router, run_serve, serve_listener, ReplHandle,
    ReplLog, Replicator, ServeEngine, ServeOptions, ServeRequest,
    ShardConfig,
};
use relcount::util::json::Json;

fn build_db(backend: Backend, kernel: JoinKernel) -> Database {
    let mut db = generate(&preset("uw", 0.05, 42).unwrap()).unwrap();
    db.set_backend(backend).unwrap();
    db.set_kernel(kernel);
    db
}

type ShardHandle =
    std::thread::JoinHandle<relcount::Result<relcount::serve::ServeSummary>>;

fn spawn_shard(
    db: Database,
    listener: TcpListener,
    index: usize,
    of: usize,
    workers: usize,
) -> ShardHandle {
    std::thread::spawn(move || {
        let engine = ServeEngine::build(db, MaintainConfig::default())?;
        let opts = ServeOptions {
            database: "uw".into(),
            workers,
            shard: Some(ShardConfig { index, of }),
            ..Default::default()
        };
        serve_listener(engine, listener, &opts)
    })
}

/// Send a shutdown request straight to a serving address and wait for
/// the acknowledgement.
fn shut_down(addr: &str) {
    let mut s = TcpStream::connect(addr).unwrap();
    writeln!(s, "{}", ServeRequest::Shutdown { id: 0 }.to_json().dump()).unwrap();
    let mut line = String::new();
    BufReader::new(&s).read_line(&mut line).unwrap();
}

/// Stream `input` through a TCP session at `addr` and return the raw
/// response bytes.
fn stream_through(addr: &str, input: &str) -> Vec<u8> {
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(input.as_bytes()).unwrap();
    s.shutdown(std::net::Shutdown::Write).unwrap();
    let mut out = Vec::new();
    std::io::Read::read_to_end(&mut BufReader::new(&s), &mut out).unwrap();
    out
}

#[test]
fn routed_serving_is_byte_identical_across_backends_and_kernels() {
    for backend in [Backend::Csr, Backend::Ccsr] {
        for kernel in [JoinKernel::Chain, JoinKernel::Wcoj] {
            let db = build_db(backend, kernel);
            let reqs = enumerate_requests(&db, 3, 12).unwrap();
            let mut input: String =
                reqs.iter().map(|r| r.to_json().dump() + "\n").collect();
            input.push_str(
                &(ServeRequest::Shutdown { id: 99 }.to_json().dump() + "\n"),
            );

            // single-process reference over the identical request stream
            let engine =
                ServeEngine::build(db.clone(), MaintainConfig::default())
                    .unwrap();
            let mut reference = Vec::new();
            let opts =
                ServeOptions { database: "uw".into(), ..Default::default() };
            run_serve(
                engine,
                std::io::Cursor::new(input.clone()),
                &mut reference,
                &opts,
            )
            .unwrap();

            // 2-shard + router topology on localhost
            let shard_listeners: Vec<TcpListener> = (0..2)
                .map(|_| TcpListener::bind("127.0.0.1:0").unwrap())
                .collect();
            let addrs: Vec<String> = shard_listeners
                .iter()
                .map(|l| l.local_addr().unwrap().to_string())
                .collect();
            let shards: Vec<ShardHandle> = shard_listeners
                .into_iter()
                .enumerate()
                .map(|(i, l)| spawn_shard(db.clone(), l, i, 2, 1))
                .collect();
            let router_listener = TcpListener::bind("127.0.0.1:0").unwrap();
            let router_addr =
                router_listener.local_addr().unwrap().to_string();
            let router_db = db.clone();
            let router_addrs = addrs.clone();
            let router = std::thread::spawn(move || {
                let opts = ServeOptions {
                    database: "uw".into(),
                    ..Default::default()
                };
                run_router(router_db, &router_addrs, router_listener, &opts)
            });

            let routed = stream_through(&router_addr, &input);
            let summary = router.join().unwrap().unwrap();
            for addr in &addrs {
                shut_down(addr);
            }
            for h in shards {
                let s = h.join().unwrap().unwrap();
                assert_eq!(s.errors, 0, "{backend:?}/{kernel:?} shard errors");
            }

            assert_eq!(
                routed, reference,
                "routed responses diverged from single-process serving \
                 ({backend:?}/{kernel:?})"
            );
            assert_eq!(summary.errors, 0);
            assert_eq!(summary.requests as usize, reqs.len() + 1);
            assert!(summary.rows.iter().all(|r| r.shards == 2));
        }
    }
}

#[test]
fn dead_shard_is_a_typed_route_error_and_a_restart_recovers() {
    let db = build_db(Backend::Csr, JoinKernel::Chain);
    let req = enumerate_requests(&db, 3, 1).unwrap().remove(0);

    let l0 = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr0 = l0.local_addr().unwrap().to_string();
    let l1 = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr1 = l1.local_addr().unwrap().to_string();
    let shard0 = spawn_shard(db.clone(), l0, 0, 2, 1);
    let shard1 = spawn_shard(db.clone(), l1, 1, 2, 1);

    let router_listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let router_addr = router_listener.local_addr().unwrap().to_string();
    let router_db = db.clone();
    let router_addrs = vec![addr0.clone(), addr1.clone()];
    let router = std::thread::spawn(move || {
        let opts = ServeOptions { database: "uw".into(), ..Default::default() };
        run_router(router_db, &router_addrs, router_listener, &opts)
    });

    let mut client = TcpStream::connect(&router_addr).unwrap();
    let mut reader = BufReader::new(client.try_clone().unwrap());
    let ask = |client: &mut TcpStream,
                   reader: &mut BufReader<TcpStream>,
                   line: &str| {
        writeln!(client, "{line}").unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        Json::parse(&resp).unwrap()
    };
    let line = req.to_json().dump();

    // healthy topology answers
    let before = ask(&mut client, &mut reader, &line);
    assert_eq!(before.get("ok"), Some(&Json::Bool(true)));

    // kill shard 0: the router must answer with a typed route error,
    // not a partial (wrong) count
    shut_down(&addr0);
    shard0.join().unwrap().unwrap();
    let during = ask(&mut client, &mut reader, &line);
    assert_eq!(during.get("ok"), Some(&Json::Bool(false)));
    let msg = during.get("error").and_then(Json::as_str).unwrap();
    assert!(msg.starts_with("route error: shard "), "{msg}");

    // restart the shard on the same address (fresh engine, same state):
    // the router's per-request reconnect picks it back up
    let l0b = TcpListener::bind(&addr0).unwrap();
    let shard0b = spawn_shard(db.clone(), l0b, 0, 2, 1);
    let after = ask(&mut client, &mut reader, &line);
    assert_eq!(after.get("ok"), Some(&Json::Bool(true)), "{after:?}");
    assert_eq!(after.get("digest"), before.get("digest"));
    assert_eq!(after.get("rows"), before.get("rows"));

    let shutdown_line = ServeRequest::Shutdown { id: 9 }.to_json().dump();
    let done = ask(&mut client, &mut reader, &shutdown_line);
    assert_eq!(done.get("ok"), Some(&Json::Bool(true)));
    drop(client);
    let summary = router.join().unwrap().unwrap();
    shut_down(&addr0);
    shut_down(&addr1);
    shard0b.join().unwrap().unwrap();
    shard1.join().unwrap().unwrap();

    assert_eq!(summary.requests, 4);
    assert_eq!(summary.errors, 1, "exactly the dead-shard request failed");
}

#[test]
fn follower_publishes_the_leaders_epochs_bit_identically() {
    let db = build_db(Backend::Csr, JoinKernel::Chain);
    let mut leader =
        ServeEngine::build(db.clone(), MaintainConfig::default()).unwrap();
    let log = Arc::new(ReplLog::new());
    for i in 0..3u64 {
        let batch = churn_batch(leader.db(), 0.1, 7 ^ (i + 1));
        leader.apply_publish(&batch).unwrap();
        log.append(ReplRecord {
            epoch: leader.epoch(),
            digest: leader.digest(),
            batch,
        });
    }
    log.close();

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let acceptor = Replicator::spawn(listener, log.clone()).unwrap();

    let mut follower =
        ServeEngine::build(db, MaintainConfig::default()).unwrap();
    let handle = ReplHandle::new();
    let (publishes, failures) =
        follow(&addr, &mut follower, Some(&handle), Duration::from_millis(1));
    acceptor.shutdown();

    assert!(failures.is_empty(), "{failures:?}");
    assert_eq!(publishes, 3);
    assert_eq!(follower.epoch(), leader.epoch());
    assert_eq!(
        follower.digest(),
        leader.digest(),
        "follower must republish the leader's generations bit-identically"
    );
    assert_eq!(handle.applied_epoch(), 3);
    assert_eq!(handle.lag(), 0);
    assert!(handle.healthy());
}

#[test]
fn follower_detects_a_tampered_leader_digest() {
    let db = build_db(Backend::Csr, JoinKernel::Chain);
    let mut leader =
        ServeEngine::build(db.clone(), MaintainConfig::default()).unwrap();
    let batch = churn_batch(leader.db(), 0.1, 13);
    leader.apply_publish(&batch).unwrap();
    let log = Arc::new(ReplLog::new());
    log.append(ReplRecord {
        epoch: leader.epoch(),
        digest: leader.digest() ^ 1, // bit-flip the claimed digest
        batch,
    });
    log.close();

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let acceptor = Replicator::spawn(listener, log.clone()).unwrap();

    let mut follower =
        ServeEngine::build(db, MaintainConfig::default()).unwrap();
    let handle = ReplHandle::new();
    let (_publishes, failures) =
        follow(&addr, &mut follower, Some(&handle), Duration::ZERO);
    acceptor.shutdown();

    assert!(!failures.is_empty(), "digest divergence must be reported");
    assert!(!handle.healthy(), "divergence marks the follower unhealthy");
}

#[test]
fn bad_partial_requests_are_rejected_typed() {
    // a plain server (no shard role) must reject partial ops, and a
    // shard must reject a slice identity that isn't its own
    let db = build_db(Backend::Csr, JoinKernel::Chain);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let plain = std::thread::spawn({
        let db = db.clone();
        move || {
            let engine =
                ServeEngine::build(db, MaintainConfig::default()).unwrap();
            let opts =
                ServeOptions { database: "uw".into(), ..Default::default() };
            serve_listener(engine, listener, &opts)
        }
    });
    let req =
        ServeRequest::PCount { id: 1, chain: vec![], vars: vec![] }.to_json();
    let mut s = TcpStream::connect(&addr).unwrap();
    writeln!(s, "{}", req.dump()).unwrap();
    let mut line = String::new();
    let mut r = BufReader::new(s.try_clone().unwrap());
    r.read_line(&mut line).unwrap();
    let resp = Json::parse(&line).unwrap();
    assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));
    let msg = resp.get("error").and_then(Json::as_str).unwrap();
    assert!(msg.contains("shard"), "{msg}");
    writeln!(s, "{}", ServeRequest::Shutdown { id: 2 }.to_json().dump())
        .unwrap();
    line.clear();
    r.read_line(&mut line).unwrap();
    let summary = plain.join().unwrap().unwrap();
    assert_eq!(summary.errors, 1);

    // sanity: a delete that never existed still fails loudly end to end
    // (the shard engines share the serve engine's publish machinery)
    let mut engine =
        ServeEngine::build(db, MaintainConfig::default()).unwrap();
    let bogus = relcount::delta::DeltaBatch::new(vec![DeltaOp::DeleteLink {
        rel: 0,
        from: u32::MAX,
        to: u32::MAX,
    }]);
    assert!(engine.apply_publish(&bogus).is_err());
}
