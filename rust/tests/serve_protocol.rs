//! The `relcount serve` wire-format contract, end to end through the
//! public API: every response line must be independently verifiable
//! against a from-scratch strategy on the served generation's database
//! — the digest a client reads IS the bit-identity witness — and the
//! full response stream must be byte-identical across worker counts,
//! malformed lines included.

use relcount::datagen::{generator::generate, presets::preset};
use relcount::delta::MaintainConfig;
use relcount::learn::score::bdeu_from_ct;
use relcount::serve::{enumerate_requests, run_serve, ServeEngine, ServeOptions, ServeRequest};
use relcount::strategies::traits::{CountingStrategy, StrategyConfig};
use relcount::strategies::StrategyKind;
use relcount::util::json::Json;

#[test]
fn every_response_verifies_against_a_fresh_strategy() {
    let db = generate(&preset("uw", 0.05, 42).unwrap()).unwrap();
    let reqs = enumerate_requests(&db, 3, 30).unwrap();
    let input: String = reqs.iter().map(|r| r.to_json().dump() + "\n").collect();

    let engine = ServeEngine::build(db.clone(), MaintainConfig::default()).unwrap();
    let mut out = Vec::new();
    let opts = ServeOptions { database: "uw".into(), workers: 2, ..Default::default() };
    let summary =
        run_serve(engine, std::io::Cursor::new(input), &mut out, &opts).unwrap();
    assert_eq!(summary.requests as usize, reqs.len());
    assert_eq!(summary.errors, 0);

    let mut fresh = StrategyKind::OnDemand.build(&db, StrategyConfig::default()).unwrap();
    let text = String::from_utf8(out).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), reqs.len(), "one response line per request, in order");
    for (req, line) in reqs.iter().zip(&lines) {
        let resp = Json::parse(line).unwrap();
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{line}");
        assert_eq!(resp.get("id").unwrap().as_usize().unwrap() as u64, req.id());
        // static feed: everything answers from generation 0
        assert_eq!(resp.get("epoch").unwrap().as_usize(), Some(0));
        match req {
            ServeRequest::Count { vars, ctx, .. } => {
                let want = fresh.ct_for_family(vars, ctx).unwrap();
                assert_eq!(
                    resp.get("digest").unwrap().as_str().unwrap(),
                    format!("{:016x}", want.digest()),
                    "served digest must match a from-scratch count: {line}"
                );
                let total: i128 = want.iter_rows().map(|(_, c)| c).sum();
                assert_eq!(resp.get("total").unwrap().as_f64(), Some(total as f64));
                assert_eq!(
                    resp.get("rows").unwrap().as_arr().unwrap().len(),
                    want.n_rows()
                );
            }
            ServeRequest::Score { vars, ctx, child, n_prime, .. } => {
                let ct = fresh.ct_for_family(vars, ctx).unwrap();
                let want = bdeu_from_ct(&ct, child, *n_prime).unwrap();
                let got = resp.get("score").unwrap().as_f64().unwrap();
                assert!(
                    (got - want).abs() <= 1e-9 * want.abs().max(1.0),
                    "served score {got} != fresh {want}: {line}"
                );
            }
            _ => unreachable!("enumerate_requests emits counts and scores only"),
        }
    }
}

#[test]
fn response_stream_is_byte_identical_across_worker_counts() {
    let db = generate(&preset("uw", 0.05, 42).unwrap()).unwrap();
    let reqs = enumerate_requests(&db, 3, 24).unwrap();
    let mut input: String = reqs.iter().map(|r| r.to_json().dump() + "\n").collect();
    // malformed and unknown-op lines must also answer identically
    input.push_str("definitely not json\n");
    input.push_str("{\"id\":99,\"op\":\"explode\"}\n");

    let mut streams = Vec::new();
    for workers in [1usize, 4] {
        let engine = ServeEngine::build(db.clone(), MaintainConfig::default()).unwrap();
        let mut out = Vec::new();
        let opts = ServeOptions {
            database: "uw".into(),
            workers,
            batch_max: 8,
            ..Default::default()
        };
        let summary = run_serve(
            engine,
            std::io::Cursor::new(input.clone()),
            &mut out,
            &opts,
        )
        .unwrap();
        assert_eq!(summary.requests as usize, reqs.len() + 2);
        assert_eq!(summary.errors, 2);
        streams.push(out);
    }
    assert_eq!(
        streams[0], streams[1],
        "the response stream is part of the bit-identity contract"
    );
}
