//! The central correctness invariant of the paper's Table 2: all three
//! count-caching strategies are *interchangeable* — for every family and
//! context they must return bit-identical complete ct-tables, equal to
//! brute-force grounding enumeration.

use relcount::bench::driver::{
    run_coordinated_with, run_strategy, run_strategy_with, Workload,
};
use relcount::ct::cttable::CtTable;
use relcount::ct::mobius::brute_force_complete;
use relcount::datagen::{generator::generate, presets::preset};
use relcount::db::catalog::Database;
use relcount::db::fixtures::university_db;
use relcount::lattice::Lattice;
use relcount::learn::search::SearchConfig;
use relcount::meta::rvar::RVar;
use relcount::strategies::adaptive::Adaptive;
use relcount::strategies::traits::{CountingStrategy, StrategyConfig};
use relcount::strategies::StrategyKind;

/// Every family with <= 3 variables drawn from a lattice point's var set.
fn families_of(db: &Database, max_vars: usize) -> Vec<(Vec<RVar>, Vec<usize>)> {
    let lattice = Lattice::build(&db.schema, 3).unwrap();
    let mut out = Vec::new();
    for p in &lattice.points {
        let vars = p.all_vars();
        let n = vars.len();
        // singletons, pairs, triples (bounded for test time)
        for i in 0..n {
            out.push((vec![vars[i]], p.pops.clone()));
            for j in (i + 1)..n {
                out.push((vec![vars[i], vars[j]], p.pops.clone()));
                if max_vars >= 3 {
                    for k in (j + 1)..n.min(j + 4) {
                        out.push((vec![vars[i], vars[j], vars[k]], p.pops.clone()));
                    }
                }
            }
        }
    }
    out
}

fn assert_tables_equal(a: &CtTable, b: &CtTable, what: &str) {
    assert_eq!(a.n_rows(), b.n_rows(), "{what}: row count");
    for (vals, c) in b.iter_rows() {
        assert_eq!(a.get(&vals).unwrap(), c, "{what} at {vals:?}");
    }
}

#[test]
fn all_strategies_agree_on_university() {
    let db = university_db();
    let fams = families_of(&db, 3);
    assert!(fams.len() > 50);
    let mut strategies: Vec<Box<dyn CountingStrategy>> = StrategyKind::ALL
        .iter()
        .map(|k| k.build(&db, StrategyConfig::default()).unwrap())
        .collect();
    for (vars, ctx) in &fams {
        let reference = strategies[0].ct_for_family(vars, ctx).unwrap();
        for s in strategies.iter_mut().skip(1) {
            let ct = s.ct_for_family(vars, ctx).unwrap();
            assert_tables_equal(&ct, &reference, &format!("{vars:?}"));
        }
    }
}

#[test]
fn strategies_match_brute_force_on_university() {
    let db = university_db();
    let mut hybrid = StrategyKind::Hybrid.build(&db, StrategyConfig::default()).unwrap();
    for (vars, ctx) in families_of(&db, 3) {
        let ct = hybrid.ct_for_family(&vars, &ctx).unwrap();
        let brute = brute_force_complete(&db, &vars, &ctx).unwrap();
        assert_tables_equal(&ct, &brute, &format!("{vars:?}"));
    }
}

#[test]
fn all_strategies_agree_on_scaled_presets() {
    // triangle-shaped schemas (hepatitis, financial) are the regression
    // zone for lattice-cache key collisions and disconnected subsets
    for name in ["uw", "hepatitis", "financial", "mutagenesis"] {
        let cfg = preset(name, 0.02, 42).unwrap();
        let db = generate(&cfg).unwrap();
        let fams = families_of(&db, 2);
        let mut strategies: Vec<Box<dyn CountingStrategy>> = StrategyKind::ALL
            .iter()
            .map(|k| k.build(&db, StrategyConfig::default()).unwrap())
            .collect();
        for (vars, ctx) in &fams {
            let reference = strategies[0].ct_for_family(vars, ctx).unwrap();
            for s in strategies.iter_mut().skip(1) {
                let ct = s.ct_for_family(vars, ctx).unwrap();
                assert_tables_equal(&ct, &reference, &format!("{name} {vars:?}"));
            }
        }
    }
}

#[test]
fn complete_tables_conserve_population_product() {
    let db = university_db();
    for kind in StrategyKind::ALL {
        let mut s = kind.build(&db, StrategyConfig::default()).unwrap();
        for (vars, ctx) in families_of(&db, 2) {
            let ct = s.ct_for_family(&vars, &ctx).unwrap();
            assert_eq!(
                ct.total().unwrap() as u64,
                db.population_product(&ctx),
                "{} {vars:?} ctx {ctx:?}",
                kind.name()
            );
            ct.assert_counts_nonnegative().unwrap();
        }
    }
}

#[test]
fn precount_serves_everything_by_projection_after_prepare() {
    let db = university_db();
    let mut s = StrategyKind::Precount.build(&db, StrategyConfig::default()).unwrap();
    s.prepare().unwrap();
    let joins_after_prepare = s.report().join_stats.chain_queries;
    for (vars, ctx) in families_of(&db, 3) {
        s.ct_for_family(&vars, &ctx).unwrap();
    }
    // no further joins: the definition of pre-counting
    assert_eq!(s.report().join_stats.chain_queries, joins_after_prepare);
}

/// The three reference budgets of the ADAPTIVE planner, paired with the
/// fixed strategy each reproduces: 0 -> ONDEMAND (nothing pre-counted),
/// the HYBRID-equivalent budget (marginals + all positives), and
/// unlimited -> PRECOUNT (complete tables resident).
fn reference_budgets(db: &Database) -> Vec<(Option<u64>, StrategyKind)> {
    let hb = Adaptive::new(db, StrategyConfig::default())
        .unwrap()
        .plan()
        .hybrid_budget();
    vec![
        (Some(0), StrategyKind::OnDemand),
        (Some(hb), StrategyKind::Hybrid),
        (None, StrategyKind::Precount),
    ]
}

#[test]
fn adaptive_cts_bit_identical_at_reference_budgets() {
    let db = university_db();
    let fams = families_of(&db, 3);
    for (budget, twin) in reference_budgets(&db) {
        let cfg = StrategyConfig { mem_budget: budget, ..Default::default() };
        let mut adaptive = StrategyKind::Adaptive.build(&db, cfg).unwrap();
        let mut fixed = twin.build(&db, StrategyConfig::default()).unwrap();
        for (vars, ctx) in &fams {
            let a = adaptive.ct_for_family(vars, ctx).unwrap();
            let f = fixed.ct_for_family(vars, ctx).unwrap();
            assert_tables_equal(&a, &f, &format!("budget {budget:?} {vars:?}"));
        }
        // the reference budgets reproduce the twins' counting workloads
        let (a_rep, f_rep) = (adaptive.report(), fixed.report());
        assert_eq!(
            a_rep.join_stats.chain_queries, f_rep.join_stats.chain_queries,
            "budget {budget:?} vs {}",
            twin.name()
        );
    }
}

#[test]
fn adaptive_cts_match_on_scaled_presets() {
    for name in ["uw", "hepatitis"] {
        let cfg = preset(name, 0.02, 42).unwrap();
        let db = generate(&cfg).unwrap();
        let fams = families_of(&db, 2);
        let mut reference =
            StrategyKind::Hybrid.build(&db, StrategyConfig::default()).unwrap();
        for (budget, _) in reference_budgets(&db) {
            let scfg = StrategyConfig { mem_budget: budget, ..Default::default() };
            let mut adaptive = StrategyKind::Adaptive.build(&db, scfg).unwrap();
            for (vars, ctx) in &fams {
                let a = adaptive.ct_for_family(vars, ctx).unwrap();
                let r = reference.ct_for_family(vars, ctx).unwrap();
                assert_tables_equal(&a, &r, &format!("{name} {budget:?} {vars:?}"));
            }
        }
    }
}

#[test]
fn adaptive_learns_identical_models_and_bdeu_bits() {
    let db = university_db();
    let cfg = SearchConfig::default();
    for (budget, twin) in reference_budgets(&db) {
        let base = run_strategy(&db, "u", twin, Workload::Learn(cfg), None)
            .unwrap()
            .model
            .unwrap();
        let scfg = StrategyConfig {
            mem_budget: budget,
            max_chain_length: cfg.max_chain_length,
            ..Default::default()
        };
        let m = run_strategy_with(&db, "u", StrategyKind::Adaptive, Workload::Learn(cfg), scfg)
            .unwrap()
            .model
            .unwrap();
        assert_eq!(m.bn.nodes, base.bn.nodes, "budget {budget:?}");
        assert_eq!(m.bn.parents, base.bn.parents, "budget {budget:?}");
        assert_eq!(
            m.total_score.to_bits(),
            base.total_score.to_bits(),
            "budget {budget:?} vs {}: {} vs {}",
            twin.name(),
            m.total_score,
            base.total_score
        );
    }
}

#[test]
fn adaptive_budgets_bit_identical_under_four_workers() {
    let db = university_db();
    let cfg = SearchConfig::default();
    for (budget, twin) in reference_budgets(&db) {
        let base = run_strategy(&db, "u", twin, Workload::Learn(cfg), None)
            .unwrap()
            .model
            .unwrap();
        let scfg = StrategyConfig {
            mem_budget: budget,
            max_chain_length: cfg.max_chain_length,
            ..Default::default()
        };
        let par = run_coordinated_with(
            &db,
            "u",
            StrategyKind::Adaptive,
            Workload::Learn(cfg),
            scfg,
            4,
        )
        .unwrap()
        .model
        .unwrap();
        assert_eq!(par.bn.nodes, base.bn.nodes, "budget {budget:?} w=4");
        assert_eq!(par.bn.parents, base.bn.parents, "budget {budget:?} w=4");
        assert_eq!(
            par.total_score.to_bits(),
            base.total_score.to_bits(),
            "budget {budget:?} w=4 vs {}",
            twin.name()
        );
    }
}

#[test]
fn ondemand_join_counts_dwarf_hybrid() {
    // the paper's JOIN problem, as a counted (scale-free) invariant
    let cfg = preset("hepatitis", 0.05, 7).unwrap();
    let db = generate(&cfg).unwrap();
    let fams = families_of(&db, 2);
    let mut hybrid = StrategyKind::Hybrid.build(&db, StrategyConfig::default()).unwrap();
    let mut ondemand =
        StrategyKind::OnDemand.build(&db, StrategyConfig::default()).unwrap();
    hybrid.prepare().unwrap();
    for (vars, ctx) in &fams {
        hybrid.ct_for_family(vars, ctx).unwrap();
        ondemand.ct_for_family(vars, ctx).unwrap();
    }
    let h = hybrid.report().join_stats.chain_queries;
    let o = ondemand.report().join_stats.chain_queries;
    assert!(
        o > 10 * h,
        "ONDEMAND should JOIN far more than HYBRID (o={o}, h={h})"
    );
}
