//! The central correctness invariant of the paper's Table 2: all three
//! count-caching strategies are *interchangeable* — for every family and
//! context they must return bit-identical complete ct-tables, equal to
//! brute-force grounding enumeration.

use relcount::ct::cttable::CtTable;
use relcount::ct::mobius::brute_force_complete;
use relcount::datagen::{generator::generate, presets::preset};
use relcount::db::catalog::Database;
use relcount::db::fixtures::university_db;
use relcount::lattice::Lattice;
use relcount::meta::rvar::RVar;
use relcount::strategies::traits::{CountingStrategy, StrategyConfig};
use relcount::strategies::StrategyKind;

/// Every family with <= 3 variables drawn from a lattice point's var set.
fn families_of(db: &Database, max_vars: usize) -> Vec<(Vec<RVar>, Vec<usize>)> {
    let lattice = Lattice::build(&db.schema, 3).unwrap();
    let mut out = Vec::new();
    for p in &lattice.points {
        let vars = p.all_vars();
        let n = vars.len();
        // singletons, pairs, triples (bounded for test time)
        for i in 0..n {
            out.push((vec![vars[i]], p.pops.clone()));
            for j in (i + 1)..n {
                out.push((vec![vars[i], vars[j]], p.pops.clone()));
                if max_vars >= 3 {
                    for k in (j + 1)..n.min(j + 4) {
                        out.push((vec![vars[i], vars[j], vars[k]], p.pops.clone()));
                    }
                }
            }
        }
    }
    out
}

fn assert_tables_equal(a: &CtTable, b: &CtTable, what: &str) {
    assert_eq!(a.n_rows(), b.n_rows(), "{what}: row count");
    for (vals, c) in b.iter_rows() {
        assert_eq!(a.get(&vals).unwrap(), c, "{what} at {vals:?}");
    }
}

#[test]
fn all_strategies_agree_on_university() {
    let db = university_db();
    let fams = families_of(&db, 3);
    assert!(fams.len() > 50);
    let mut strategies: Vec<Box<dyn CountingStrategy>> = StrategyKind::ALL
        .iter()
        .map(|k| k.build(&db, StrategyConfig::default()).unwrap())
        .collect();
    for (vars, ctx) in &fams {
        let reference = strategies[0].ct_for_family(vars, ctx).unwrap();
        for s in strategies.iter_mut().skip(1) {
            let ct = s.ct_for_family(vars, ctx).unwrap();
            assert_tables_equal(&ct, &reference, &format!("{vars:?}"));
        }
    }
}

#[test]
fn strategies_match_brute_force_on_university() {
    let db = university_db();
    let mut hybrid = StrategyKind::Hybrid.build(&db, StrategyConfig::default()).unwrap();
    for (vars, ctx) in families_of(&db, 3) {
        let ct = hybrid.ct_for_family(&vars, &ctx).unwrap();
        let brute = brute_force_complete(&db, &vars, &ctx).unwrap();
        assert_tables_equal(&ct, &brute, &format!("{vars:?}"));
    }
}

#[test]
fn all_strategies_agree_on_scaled_presets() {
    // triangle-shaped schemas (hepatitis, financial) are the regression
    // zone for lattice-cache key collisions and disconnected subsets
    for name in ["uw", "hepatitis", "financial", "mutagenesis"] {
        let cfg = preset(name, 0.02, 42).unwrap();
        let db = generate(&cfg).unwrap();
        let fams = families_of(&db, 2);
        let mut strategies: Vec<Box<dyn CountingStrategy>> = StrategyKind::ALL
            .iter()
            .map(|k| k.build(&db, StrategyConfig::default()).unwrap())
            .collect();
        for (vars, ctx) in &fams {
            let reference = strategies[0].ct_for_family(vars, ctx).unwrap();
            for s in strategies.iter_mut().skip(1) {
                let ct = s.ct_for_family(vars, ctx).unwrap();
                assert_tables_equal(&ct, &reference, &format!("{name} {vars:?}"));
            }
        }
    }
}

#[test]
fn complete_tables_conserve_population_product() {
    let db = university_db();
    for kind in StrategyKind::ALL {
        let mut s = kind.build(&db, StrategyConfig::default()).unwrap();
        for (vars, ctx) in families_of(&db, 2) {
            let ct = s.ct_for_family(&vars, &ctx).unwrap();
            assert_eq!(
                ct.total().unwrap() as u64,
                db.population_product(&ctx),
                "{} {vars:?} ctx {ctx:?}",
                kind.name()
            );
            ct.assert_counts_nonnegative().unwrap();
        }
    }
}

#[test]
fn precount_serves_everything_by_projection_after_prepare() {
    let db = university_db();
    let mut s = StrategyKind::Precount.build(&db, StrategyConfig::default()).unwrap();
    s.prepare().unwrap();
    let joins_after_prepare = s.report().join_stats.chain_queries;
    for (vars, ctx) in families_of(&db, 3) {
        s.ct_for_family(&vars, &ctx).unwrap();
    }
    // no further joins: the definition of pre-counting
    assert_eq!(s.report().join_stats.chain_queries, joins_after_prepare);
}

#[test]
fn ondemand_join_counts_dwarf_hybrid() {
    // the paper's JOIN problem, as a counted (scale-free) invariant
    let cfg = preset("hepatitis", 0.05, 7).unwrap();
    let db = generate(&cfg).unwrap();
    let fams = families_of(&db, 2);
    let mut hybrid = StrategyKind::Hybrid.build(&db, StrategyConfig::default()).unwrap();
    let mut ondemand =
        StrategyKind::OnDemand.build(&db, StrategyConfig::default()).unwrap();
    hybrid.prepare().unwrap();
    for (vars, ctx) in &fams {
        hybrid.ct_for_family(vars, ctx).unwrap();
        ondemand.ct_for_family(vars, ctx).unwrap();
    }
    let h = hybrid.report().join_stats.chain_queries;
    let o = ondemand.report().join_stats.chain_queries;
    assert!(
        o > 10 * h,
        "ONDEMAND should JOIN far more than HYBRID (o={o}, h={h})"
    );
}
