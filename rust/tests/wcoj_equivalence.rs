//! Differential gate for the worst-case optimal join kernel
//! (`relcount::db::wcoj`): the kernel is only shippable because it is
//! *indistinguishable* from the binary chain kernel — same `CtTable`
//! digest, same `JoinStats`, same totals — on every pattern, backend
//! and worker count.  This file checks that on randomized
//! schemas/databases over every lattice point, on the hub-skewed
//! cyclic constructions under churn (against a brute-force edge-set
//! oracle, with dirty CSR rows left uncompacted so the sorted-memo
//! fallback is exercised), and through the strategy/coordinator stack.

use relcount::bench::driver::{run_coordinated, run_strategy, Workload};
use relcount::datagen::{skewed_star_db, skewed_triangle_count, skewed_triangle_db};
use relcount::db::catalog::Database;
use relcount::db::index::Backend;
use relcount::db::query::{positive_chain_ct, JoinStats};
use relcount::db::schema::{Attribute, EntityType, RelationshipType, Schema};
use relcount::db::wcoj::JoinKernel;
use relcount::lattice::Lattice;
use relcount::meta::rvar::RVar;
use relcount::strategies::StrategyKind;
use relcount::util::fxhash::FxHashSet;
use relcount::util::rng::Rng;

/// Every (backend, kernel) combination of `db`.
fn variants(db: &Database) -> Vec<(String, Database)> {
    let mut out = Vec::new();
    for backend in [Backend::Csr, Backend::Hash] {
        for kernel in [JoinKernel::Chain, JoinKernel::Wcoj] {
            let mut v = db.clone();
            v.set_backend(backend).unwrap();
            v.set_kernel(kernel);
            out.push((format!("{}/{}", backend.name(), kernel.name()), v));
        }
    }
    out
}

/// Count `rels` grouped by `vars` under every (backend, kernel)
/// combination and assert the digest, the [`JoinStats`] and the total
/// are bit-identical across all four; returns the agreed total.
fn assert_kernels_agree(db: &Database, rels: &[usize], vars: &[RVar], what: &str) -> i128 {
    let mut reference: Option<(u64, JoinStats, i128)> = None;
    for (label, v) in variants(db) {
        let mut stats = JoinStats::default();
        let ct = positive_chain_ct(&v, rels, vars, &mut stats)
            .unwrap_or_else(|e| panic!("{what} [{label}]: {e}"));
        let got = (ct.digest(), stats, ct.total().unwrap());
        match &reference {
            None => reference = Some(got),
            Some(r) => assert_eq!(*r, got, "{what} [{label}]"),
        }
    }
    reference.unwrap().2
}

/// A random small schema: 2-3 entity types with 0-2 attrs, 1-3 distinct
/// relationships over distinct endpoint pairs (the same generator shape
/// as proptest_invariants.rs).
fn random_schema(rng: &mut Rng) -> Schema {
    let n_ets = 2 + rng.gen_range(2) as usize;
    let entities: Vec<EntityType> = (0..n_ets)
        .map(|i| EntityType {
            name: format!("E{i}"),
            attrs: (0..rng.gen_range(3))
                .map(|a| Attribute::new(format!("a{a}"), 2 + rng.gen_u32(2)))
                .collect(),
        })
        .collect();
    let mut pairs = Vec::new();
    for i in 0..n_ets {
        for j in 0..n_ets {
            if i != j {
                pairs.push((i, j));
            }
        }
    }
    rng.shuffle(&mut pairs);
    let n_rels = 1 + rng.gen_range(pairs.len().min(3) as u64) as usize;
    let relationships: Vec<RelationshipType> = pairs[..n_rels]
        .iter()
        .enumerate()
        .map(|(k, &(f, t))| RelationshipType {
            name: format!("R{k}"),
            from: f,
            to: t,
            attrs: (0..rng.gen_range(2))
                .map(|a| Attribute::new(format!("w{a}"), 2 + rng.gen_u32(2)))
                .collect(),
        })
        .collect();
    Schema::new(entities, relationships).unwrap()
}

/// A random small database over a random schema, link density high
/// enough that multi-relationship joins are routinely non-empty.
fn random_db(rng: &mut Rng) -> Database {
    let schema = random_schema(rng);
    let mut db = Database::empty(schema.clone());
    for (et, e) in schema.entities.iter().enumerate() {
        let n = 2 + rng.gen_range(6) as u32;
        for _ in 0..n {
            let row: Vec<u32> = e.attrs.iter().map(|a| rng.gen_u32(a.card)).collect();
            db.entities[et].push(&row).unwrap();
        }
    }
    for (rt, r) in schema.relationships.iter().enumerate() {
        let nf = db.entities[r.from].len();
        let nt = db.entities[r.to].len();
        for f in 0..nf {
            for t in 0..nt {
                if rng.gen_bool(0.4) {
                    let row: Vec<u32> =
                        r.attrs.iter().map(|a| rng.gen_u32(a.card)).collect();
                    db.rels[rt].push(f, t, &row).unwrap();
                }
            }
        }
    }
    db.build_indexes().unwrap();
    db
}

/// Live `(from, to)` pairs of `rel`, read straight off the index.
fn edge_set(db: &Database, rel: usize) -> FxHashSet<(u32, u32)> {
    let ix = db.index(rel).unwrap();
    let n_from = db.entities[db.schema.relationships[rel].from].len() as u32;
    let mut out = FxHashSet::default();
    for f in 0..n_from {
        for tid in ix.tids_from(f) {
            out.insert((f, db.rels[rel].to[tid as usize]));
        }
    }
    out
}

/// Triangle join cardinality of `skewed_triangle_db`-shaped schemas by
/// nested-loop enumeration over the edge sets.
fn brute_triangles(db: &Database) -> i128 {
    let e0 = edge_set(db, 0);
    let e1 = edge_set(db, 1);
    let e2 = edge_set(db, 2);
    let mut n = 0i128;
    for &(a, b) in &e0 {
        for &(b2, c) in &e1 {
            if b2 == b && e2.contains(&(a, c)) {
                n += 1;
            }
        }
    }
    n
}

/// Star join cardinality of `skewed_star_db`-shaped schemas:
/// `Σ_h indeg_E0(h) · outdeg_E1(h) · outdeg_E2(h)`.
fn brute_star(db: &Database) -> i128 {
    let e0 = edge_set(db, 0);
    let e1 = edge_set(db, 1);
    let e2 = edge_set(db, 2);
    let n_h = db.entities[0].len() as u32;
    let mut n = 0i128;
    for h in 0..n_h {
        let d0 = e0.iter().filter(|&&(_, t)| t == h).count() as i128;
        let d1 = e1.iter().filter(|&&(f, _)| f == h).count() as i128;
        let d2 = e2.iter().filter(|&&(f, _)| f == h).count() as i128;
        n += d0 * d1 * d2;
    }
    n
}

const CASES: u64 = 40;

#[test]
fn prop_wcoj_matches_chain_on_random_lattices() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed);
        let db = random_db(&mut rng);
        let lattice = Lattice::build(&db.schema, 3).unwrap();
        for p in &lattice.points {
            let what = format!("seed {seed} point {:?}", p.rels);
            assert_kernels_agree(&db, &p.rels, &p.attr_vars, &what);
        }
    }
}

#[test]
fn prop_wcoj_matches_chain_on_indicator_only_queries() {
    // empty var lists take the collapse-last fast path in the WCOJ
    // kernel; the grouped queries above never do
    for seed in 500..500 + CASES {
        let mut rng = Rng::new(seed);
        let db = random_db(&mut rng);
        let lattice = Lattice::build(&db.schema, 3).unwrap();
        for p in &lattice.points {
            let what = format!("seed {seed} point {:?} ungrouped", p.rels);
            assert_kernels_agree(&db, &p.rels, &[], &what);
        }
    }
}

#[test]
fn triangle_tracks_brute_force_under_churn() {
    let mut db = skewed_triangle_db(10).unwrap();
    assert_eq!(brute_triangles(&db), skewed_triangle_count(10) as i128);
    let mut rng = Rng::new(0xC0FFEE);
    for step in 0..6 {
        // churn: drop one link and add a few per relationship, leaving
        // the touched CSR rows dirty (overlays force the memo fallback)
        for rel in 0..3 {
            let es: Vec<(u32, u32)> = edge_set(&db, rel).into_iter().collect();
            let (f, t) = es[rng.gen_range(es.len() as u64) as usize];
            db.delete_link(rel, f, t).unwrap();
            for _ in 0..3 {
                let f = rng.gen_u32(10);
                let t = rng.gen_u32(10);
                if !edge_set(&db, rel).contains(&(f, t)) {
                    db.insert_link(rel, f, t, &[]).unwrap();
                }
            }
        }
        if step == 3 {
            db.compact_indexes();
        }
        let want = brute_triangles(&db);
        let got = assert_kernels_agree(&db, &[0, 1, 2], &[], &format!("step {step}"));
        assert_eq!(got, want, "step {step}");
    }
}

#[test]
fn star_tracks_brute_force_under_churn() {
    let mut db = skewed_star_db(9).unwrap();
    let mut rng = Rng::new(42);
    for step in 0..4 {
        for rel in 0..3 {
            let es: Vec<(u32, u32)> = edge_set(&db, rel).into_iter().collect();
            let (f, t) = es[rng.gen_range(es.len() as u64) as usize];
            db.delete_link(rel, f, t).unwrap();
            let f = rng.gen_u32(9);
            let t = rng.gen_u32(9);
            if !edge_set(&db, rel).contains(&(f, t)) {
                db.insert_link(rel, f, t, &[]).unwrap();
            }
        }
        let want = brute_star(&db);
        let got = assert_kernels_agree(&db, &[0, 1, 2], &[], &format!("step {step}"));
        assert_eq!(got, want, "step {step}");
    }
}

#[test]
fn kernel_is_invisible_through_strategies_and_coordinator() {
    let db = skewed_triangle_db(12).unwrap();
    let mut wcoj_db = db.clone();
    wcoj_db.set_kernel(JoinKernel::Wcoj);
    for kind in StrategyKind::ALL_WITH_ADAPTIVE {
        let base = run_strategy(&db, "tri", kind, Workload::PrepareOnly, None).unwrap();
        let seq =
            run_strategy(&wcoj_db, "tri", kind, Workload::PrepareOnly, None).unwrap();
        assert_eq!(seq.cache_digest, base.cache_digest, "{kind:?} sequential");
        for workers in [1, 4] {
            let par =
                run_coordinated(&wcoj_db, "tri", kind, Workload::PrepareOnly, None, workers)
                    .unwrap();
            assert_eq!(par.cache_digest, base.cache_digest, "{kind:?} x{workers}");
        }
    }
}
