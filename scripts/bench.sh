#!/usr/bin/env bash
# Bench trajectory: run the coordinator scaling sweep and the ADAPTIVE
# planner sweep on tiny presets and emit machine-readable JSON at the
# repo root, so perf numbers accumulate across PRs.
#
#   scripts/bench.sh                       # writes BENCH_scaling.json,
#                                          #        BENCH_planner.json
#   RELCOUNT_SCALE=0.1 scripts/bench.sh    # heavier sweep
#
# Keep the defaults small: CI runs this on shared runners, and the goal
# is a comparable trajectory, not absolute numbers.
set -euo pipefail

if ! command -v cargo >/dev/null 2>&1; then
    echo "bench.sh: ERROR: cargo not found on PATH." >&2
    echo "bench.sh: install a Rust toolchain (rustup.rs) or run inside the CI image." >&2
    exit 1
fi

ROOT="$(cd "$(dirname "$0")/.." && pwd)"
cd "$ROOT/rust"

SCALE="${RELCOUNT_SCALE:-0.03}"
PRESETS="${RELCOUNT_PRESETS:-uw,mondial}"
BUDGET_S="${RELCOUNT_BUDGET_S:-120}"

cargo build --release --quiet

echo "== exp scaling (scale $SCALE, presets $PRESETS) =="
./target/release/relcount exp scaling \
    --scale "$SCALE" --presets "$PRESETS" --budget-s "$BUDGET_S" \
    --workers-list 1,2 --json "$ROOT/BENCH_scaling.json"

echo "== exp planner (scale $SCALE, presets $PRESETS) =="
./target/release/relcount exp planner \
    --scale "$SCALE" --presets "$PRESETS" --budget-s "$BUDGET_S" \
    --json "$ROOT/BENCH_planner.json"

echo "== exp churn (scale $SCALE, presets $PRESETS) =="
./target/release/relcount exp churn \
    --scale "$SCALE" --presets "$PRESETS" --budget-s "$BUDGET_S" \
    --churn 0.01,0.05 --json "$ROOT/BENCH_churn.json"

echo "== exp serve (scale $SCALE, presets $PRESETS) =="
./target/release/relcount exp serve \
    --scale "$SCALE" --presets "$PRESETS" --budget-s "$BUDGET_S" \
    --workers 2 --churn-frac 0.05 --churn-steps 3 \
    --json "$ROOT/BENCH_serve.json"

echo "bench.sh: wrote BENCH_scaling.json, BENCH_planner.json, BENCH_churn.json and BENCH_serve.json"
