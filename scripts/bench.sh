#!/usr/bin/env bash
# Bench trajectory: run the coordinator scaling sweep, the ADAPTIVE
# planner sweep, the churn differential and the serve throughput rows on
# small presets, emitting machine-readable JSON at the repo root so perf
# numbers accumulate across PRs.
#
#   scripts/bench.sh                          # local defaults
#   RELCOUNT_BENCH_SCALE=ci scripts/bench.sh  # CI profile: smallest
#                                             # preset, 2 workers, tight
#                                             # budget (the bench-smoke
#                                             # job runs exactly this)
#   RELCOUNT_BENCH_SCALE=full scripts/bench.sh  # heavier local sweep
#
# Every knob is env-overridable on top of the profile, so the same
# script serves the CI job and local sweeps:
#   RELCOUNT_SCALE         dataset scale factor        (default 0.03)
#   RELCOUNT_PRESETS       comma-separated presets     (default uw,mondial)
#   RELCOUNT_BUDGET_S      per-cell budget, seconds    (default 120)
#   RELCOUNT_WORKERS_LIST  scaling sweep worker list   (default 1,2)
#   RELCOUNT_WORKERS       churn/serve worker count    (default 2)
#   RELCOUNT_CHURN_FRACS   churn batch fractions       (default 0.01,0.05)
#   RELCOUNT_SHARDS        exp serve shard count       (default 2)
#   RELCOUNT_SESSIONS      exp serve client sessions   (default 2)
#
# Keep the defaults small: CI runs this on shared runners, and the goal
# is a comparable trajectory, not absolute numbers.
set -euo pipefail

if ! command -v cargo >/dev/null 2>&1; then
    echo "bench.sh: ERROR: cargo not found on PATH." >&2
    echo "bench.sh: install a Rust toolchain (rustup.rs) or run inside the CI image." >&2
    exit 1
fi

ROOT="$(cd "$(dirname "$0")/.." && pwd)"
cd "$ROOT/rust"

# Profile defaults (RELCOUNT_BENCH_SCALE=ci|full|<unset>), individually
# overridable by the RELCOUNT_* variables below.
case "${RELCOUNT_BENCH_SCALE:-}" in
    ci)
        D_SCALE=0.02 D_PRESETS=uw D_BUDGET=120 D_WLIST=1,2 D_WORKERS=2 \
            D_CHURN=0.05 D_SHARDS=2 D_SESSIONS=2
        ;;
    full)
        D_SCALE=0.1 D_PRESETS=uw,mondial,hepatitis D_BUDGET=300 D_WLIST=1,2,4 \
            D_WORKERS=4 D_CHURN=0.01,0.05 D_SHARDS=2 D_SESSIONS=4
        ;;
    "")
        D_SCALE=0.03 D_PRESETS=uw,mondial D_BUDGET=120 D_WLIST=1,2 D_WORKERS=2 \
            D_CHURN=0.01,0.05 D_SHARDS=2 D_SESSIONS=2
        ;;
    *)
        echo "bench.sh: RELCOUNT_BENCH_SCALE expects ci|full (or unset), got '${RELCOUNT_BENCH_SCALE}'" >&2
        exit 1
        ;;
esac

SCALE="${RELCOUNT_SCALE:-$D_SCALE}"
PRESETS="${RELCOUNT_PRESETS:-$D_PRESETS}"
BUDGET_S="${RELCOUNT_BUDGET_S:-$D_BUDGET}"
WORKERS_LIST="${RELCOUNT_WORKERS_LIST:-$D_WLIST}"
WORKERS="${RELCOUNT_WORKERS:-$D_WORKERS}"
CHURN_FRACS="${RELCOUNT_CHURN_FRACS:-$D_CHURN}"
SHARDS="${RELCOUNT_SHARDS:-$D_SHARDS}"
SESSIONS="${RELCOUNT_SESSIONS:-$D_SESSIONS}"

echo "bench.sh: scale=$SCALE presets=$PRESETS budget=${BUDGET_S}s" \
     "workers-list=$WORKERS_LIST workers=$WORKERS churn=$CHURN_FRACS" \
     "shards=$SHARDS sessions=$SESSIONS"

cargo build --release --quiet

echo "== exp scaling (scale $SCALE, presets $PRESETS) =="
./target/release/relcount exp scaling \
    --scale "$SCALE" --presets "$PRESETS" --budget-s "$BUDGET_S" \
    --workers-list "$WORKERS_LIST" --json "$ROOT/BENCH_scaling.json"

echo "== exp planner (scale $SCALE, presets $PRESETS) =="
./target/release/relcount exp planner \
    --scale "$SCALE" --presets "$PRESETS" --budget-s "$BUDGET_S" \
    --json "$ROOT/BENCH_planner.json"

echo "== exp churn (scale $SCALE, presets $PRESETS) =="
./target/release/relcount exp churn \
    --scale "$SCALE" --presets "$PRESETS" --budget-s "$BUDGET_S" \
    --churn "$CHURN_FRACS" --workers "$WORKERS" --json "$ROOT/BENCH_churn.json"

echo "== exp serve (scale $SCALE, presets $PRESETS) =="
./target/release/relcount exp serve \
    --scale "$SCALE" --presets "$PRESETS" --budget-s "$BUDGET_S" \
    --workers "$WORKERS" --churn-frac 0.05 --churn-steps 3 \
    --shards "$SHARDS" --sessions "$SESSIONS" \
    --json "$ROOT/BENCH_serve.json"

echo "== exp persist (scale $SCALE, presets $PRESETS) =="
./target/release/relcount exp persist \
    --scale "$SCALE" --presets "$PRESETS" --budget-s "$BUDGET_S" \
    --workers "$WORKERS" --json "$ROOT/BENCH_persist.json"

echo "== exp estimator (scale $SCALE, presets $PRESETS) =="
./target/release/relcount exp estimator \
    --scale "$SCALE" --presets "$PRESETS" --budget-s "$BUDGET_S" \
    --json "$ROOT/BENCH_estimator.json"

echo "== exp wcoj (scale $SCALE, presets $PRESETS) =="
./target/release/relcount exp wcoj \
    --scale "$SCALE" --presets "$PRESETS" --budget-s "$BUDGET_S" \
    --json "$ROOT/BENCH_wcoj.json"

echo "== exp compress (scale $SCALE, presets $PRESETS) =="
./target/release/relcount exp compress \
    --scale "$SCALE" --presets "$PRESETS" --budget-s "$BUDGET_S" \
    --json "$ROOT/BENCH_compress.json"

echo "bench.sh: wrote BENCH_scaling.json, BENCH_planner.json, BENCH_churn.json, BENCH_serve.json, BENCH_persist.json, BENCH_estimator.json, BENCH_wcoj.json and BENCH_compress.json"
