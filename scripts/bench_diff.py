#!/usr/bin/env python3
"""Diff a fresh bench sweep against the committed baselines.

    python3 scripts/bench_diff.py <baseline_dir> <fresh_dir> [report_path]

Baselines live in `bench/baselines/` as either

  * a wrapper object ``{"provenance": "...", "rows": [...]}`` — the
    committed form, carrying where the numbers came from, or
  * a bare row array — the exact shape `scripts/bench.sh` emits, for
    drop-in promotion of a measured run (``cp BENCH_x.json
    bench/baselines/`` plus a provenance note is the upgrade path).

Behaviour per file:

  * ``provenance == "seed"`` (or an empty rows array): **record-only**.
    The run's headline values are printed into the report so the
    trajectory is visible in CI artifacts, but nothing can fail — a
    seed baseline has no trustworthy numbers to compare against.
  * anything else: every baseline headline row must reappear in the
    fresh run (matched on its identity columns) with each headline
    metric within ``RELCOUNT_BENCH_TOLERANCE`` (default 0.25, i.e.
    +/-25%) relative deviation.  The divisor is floored at
    ``RELCOUNT_BENCH_EPSILON`` (default 1e-3), so a zero or near-zero
    baseline value neither divides by zero nor manufactures a +/-inf%
    deviation out of sub-epsilon noise.  Out-of-band rows, vanished
    rows, and malformed files fail the diff.

Exit status: 0 on pass/record-only, 1 on any failure.
"""

import json
import os
import sys

# file -> (identity columns, headline metric columns)
HEADLINES = {
    "BENCH_scaling.json": (("database", "strategy", "workers"), ("wall_s",)),
    "BENCH_planner.json": (("database", "pre_fraction", "workers"), ("total_s",)),
    "BENCH_churn.json": (("database", "churn_frac", "workers"), ("speedup",)),
    "BENCH_serve.json": (
        ("database", "workers", "shards"),
        ("throughput_rps",),
    ),
    "BENCH_persist.json": (("database", "workers"), ("save_s", "load_s")),
    "BENCH_estimator.json": (
        ("database", "mode"),
        ("q_p50", "regret_saved_frac"),
    ),
    "BENCH_wcoj.json": (("database", "point"), ("speedup",)),
    "BENCH_compress.json": (
        ("database",),
        ("bytes_per_pair_ccsr", "bytes_ratio"),
    ),
}


def load_rows(path):
    """Return (provenance, rows) for a baseline or fresh file."""
    with open(path) as f:
        data = json.load(f)
    if isinstance(data, dict):
        rows = data.get("rows", [])
        provenance = data.get("provenance", "unknown")
    elif isinstance(data, list):
        rows, provenance = data, "measured"
    else:
        raise ValueError(f"{path}: expected an object or array")
    if not isinstance(rows, list):
        raise ValueError(f"{path}: rows is not an array")
    return provenance, rows


def ident(row, cols):
    return tuple((c, row.get(c)) for c in cols)


def fmt_ident(key):
    return " ".join(f"{c}={v}" for c, v in key)


def main():
    if len(sys.argv) not in (3, 4):
        print(__doc__.strip(), file=sys.stderr)
        return 2
    base_dir, fresh_dir = sys.argv[1], sys.argv[2]
    report_path = sys.argv[3] if len(sys.argv) == 4 else None
    tolerance = float(os.environ.get("RELCOUNT_BENCH_TOLERANCE", "0.25"))
    epsilon = float(os.environ.get("RELCOUNT_BENCH_EPSILON", "1e-3"))

    lines = [f"# bench diff (tolerance +/-{tolerance:.0%})", ""]
    failed = False

    for name, (id_cols, metric_cols) in sorted(HEADLINES.items()):
        base_path = os.path.join(base_dir, name)
        fresh_path = os.path.join(fresh_dir, name)
        lines.append(f"## {name}")
        if not os.path.exists(base_path):
            lines.append("FAIL: no committed baseline (seed one in bench/baselines/)")
            failed = True
            continue
        if not os.path.exists(fresh_path):
            lines.append("FAIL: fresh run missing (bench.sh did not emit it)")
            failed = True
            continue
        try:
            provenance, base_rows = load_rows(base_path)
            _, fresh_rows = load_rows(fresh_path)
        except (ValueError, json.JSONDecodeError) as e:
            lines.append(f"FAIL: unreadable: {e}")
            failed = True
            continue

        fresh_by_id = {ident(r, id_cols): r for r in fresh_rows}

        if provenance == "seed" or not base_rows:
            # Record-only: a seed baseline carries no comparable numbers.
            lines.append(f"record-only (baseline provenance: {provenance})")
            for key, row in sorted(fresh_by_id.items(), key=repr):
                vals = ", ".join(f"{m}={row.get(m)}" for m in metric_cols)
                lines.append(f"  {fmt_ident(key)}: {vals}")
            continue

        lines.append(f"comparing {len(base_rows)} baseline rows ({provenance})")
        for brow in base_rows:
            key = ident(brow, id_cols)
            frow = fresh_by_id.get(key)
            if frow is None:
                lines.append(f"FAIL {fmt_ident(key)}: row vanished from fresh run")
                failed = True
                continue
            for m in metric_cols:
                try:
                    b, f = float(brow[m]), float(frow[m])
                except (KeyError, TypeError, ValueError):
                    lines.append(f"FAIL {fmt_ident(key)}: metric {m} unreadable")
                    failed = True
                    continue
                # floor the divisor: a 0.0 baseline (or one below the
                # noise floor) must not divide by zero or turn
                # sub-epsilon jitter into an infinite relative deviation
                delta = (f - b) / max(abs(b), epsilon)
                ok = abs(delta) <= tolerance
                mark = "ok  " if ok else "FAIL"
                lines.append(
                    f"{mark} {fmt_ident(key)}: {m} {b:g} -> {f:g} ({delta:+.1%})"
                )
                failed = failed or not ok

    lines.append("")
    lines.append("RESULT: " + ("FAIL" if failed else "pass"))
    report = "\n".join(lines) + "\n"
    print(report, end="")
    if report_path:
        with open(report_path, "w") as f:
            f.write(report)
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
