#!/usr/bin/env bash
# Lint gate: run before the tier-1 suite (see EXPERIMENTS.md).
#
#   scripts/check.sh            # fmt --check + clippy -D warnings
#   scripts/check.sh --fix      # apply rustfmt instead of checking
#
# The workspace root is rust/; doc builds must stay warning-free for the
# coordinator module (rustdoc is part of its acceptance criteria).
set -euo pipefail

cd "$(dirname "$0")/../rust"

if [[ "${1:-}" == "--fix" ]]; then
    cargo fmt
else
    cargo fmt --check
fi

cargo clippy --all-targets -- -D warnings

# rustdoc warnings fail the gate too (dangling intra-doc links etc.)
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

echo "check.sh: fmt + clippy + rustdoc clean"
