#!/usr/bin/env bash
# Lint gate: run before the tier-1 suite (see EXPERIMENTS.md).
#
#   scripts/check.sh            # fmt --check + clippy -D warnings + rustdoc
#   scripts/check.sh --fix      # apply rustfmt instead of checking
#
# The crate root is rust/; doc builds must stay warning-free (rustdoc is
# part of the coordinator module's acceptance criteria).  CI runs this
# script verbatim (.github/workflows/ci.yml), so it must fail loudly —
# never silently succeed — when the toolchain is absent.
set -euo pipefail

if ! command -v cargo >/dev/null 2>&1; then
    echo "check.sh: ERROR: cargo not found on PATH." >&2
    echo "check.sh: install a Rust toolchain (rustup.rs) or run inside the CI image." >&2
    exit 1
fi

cd "$(dirname "$0")/../rust"

if [[ "${1:-}" == "--fix" ]]; then
    cargo fmt
else
    cargo fmt --check
fi

cargo clippy --all-targets -- -D warnings

# rustdoc warnings fail the gate too (dangling intra-doc links etc.)
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

echo "check.sh: fmt + clippy + rustdoc clean"
