#!/usr/bin/env bash
# Scale-out serving smoke (the scaleout-smoke CI lane).
#
# Proves, on a live localhost topology of real processes:
#
#   1. Equivalence matrix — a 2-shard + router topology answers the
#      deterministic request workload BYTE-IDENTICALLY to single-process
#      `relcount serve`, for every {csr,ccsr} x {chain,wcoj} x {1,4
#      workers} cell.  The router merges digest-checked partial counts
#      (positives sum across shards; the Möbius completion runs once at
#      the router), so a diff here is a partition or merge bug.
#   2. Chaos — SIGKILL one shard mid-session: the very next routed
#      request must answer a typed `route error` (never a wrong count),
#      and a shard restarted from its --data-dir on the same port is
#      picked back up by the router's per-request reconnect, answering
#      bit-identically to before the kill.
#   3. Replication — a follower consuming the leader's publish stream
#      (--replicate-port / --follow) must publish every generation
#      bit-identically: both processes report the same
#      `final epoch N digest D` line and the follower reports lag 0,
#      healthy.
#
#   scripts/scaleout_smoke.sh            # build + run everything
set -euo pipefail

if ! command -v cargo >/dev/null 2>&1; then
    echo "scaleout_smoke.sh: ERROR: cargo not found on PATH." >&2
    exit 1
fi

ROOT="$(cd "$(dirname "$0")/.." && pwd)"
cd "$ROOT/rust"
cargo build --release --quiet
BIN=./target/release/relcount

TMP="$(mktemp -d /tmp/scaleout.XXXXXX)"
PIDS=()
cleanup() {
    for pid in "${PIDS[@]:-}"; do
        kill -9 "$pid" 2>/dev/null || true
    done
    rm -rf "$TMP"
}
trap cleanup EXIT

# Tiny socket client: everything the smoke needs to talk to the
# topology (wait for a process to announce its port, stream a request
# file through one session, one-shot request/response).
cat > "$TMP/client.py" <<'PYEOF'
import re
import socket
import sys
import time


def waitaddr(log, prefix):
    """Print the host:port a process announced on stderr, waiting for
    the line `<prefix>... on <host:port> ...` to appear."""
    deadline = time.time() + 60
    while time.time() < deadline:
        try:
            with open(log) as f:
                for line in f:
                    if line.startswith(prefix):
                        m = re.search(r"on (\d+\.\d+\.\d+\.\d+:\d+)", line)
                        if m:
                            print(m.group(1))
                            return 0
        except FileNotFoundError:
            pass
        time.sleep(0.05)
    sys.stderr.write(f"timed out waiting for {prefix!r} in {log}\n")
    return 1


def connect(addr):
    host, port = addr.rsplit(":", 1)
    return socket.create_connection((host, int(port)), timeout=60)


def stream(addr, infile, outfile):
    """One session: send every request line, half-close, read all
    responses."""
    with connect(addr) as s, open(infile, "rb") as f:
        s.sendall(f.read())
        s.shutdown(socket.SHUT_WR)
        out = b""
        while True:
            chunk = s.recv(65536)
            if not chunk:
                break
            out += chunk
    with open(outfile, "wb") as f:
        f.write(out)
    return 0


def ask(addr, line):
    """One request, one response line, printed to stdout.  Never raises
    on transport errors -- the caller greps the response."""
    with connect(addr) as s:
        s.sendall(line.encode() + b"\n")
        r = s.makefile("rb")
        resp = r.readline()
    sys.stdout.write(resp.decode())
    return 0


cmd = sys.argv[1]
if cmd == "waitaddr":
    sys.exit(waitaddr(sys.argv[2], sys.argv[3]))
elif cmd == "stream":
    sys.exit(stream(sys.argv[2], sys.argv[3], sys.argv[4]))
elif cmd == "ask":
    sys.exit(ask(sys.argv[2], sys.argv[3]))
sys.stderr.write(f"unknown command {cmd!r}\n")
sys.exit(2)
PYEOF
CLIENT="python3 $TMP/client.py"

SHUTDOWN='{"op": "shutdown", "id": 0}'

echo "== setup: database + deterministic workload =="
"$BIN" gen --preset uw --scale 0.02 --out "$TMP/db"
"$BIN" gen-requests --db "$TMP/db" --limit 40 --out "$TMP/reqs.jsonl"
cp "$TMP/reqs.jsonl" "$TMP/reqs_shut.jsonl"
echo "$SHUTDOWN" >> "$TMP/reqs_shut.jsonl"

echo "== 1. equivalence matrix: routed vs single-process =="
for b in csr ccsr; do
  for k in chain wcoj; do
    # single-process reference for this backend/kernel cell (responses
    # are worker-invariant; the serve-smoke lane proves that)
    "$BIN" serve --db "$TMP/db" --backend "$b" --kernel "$k" \
        --requests "$TMP/reqs_shut.jsonl" \
        > "$TMP/single-$b-$k.jsonl" 2> /dev/null
    for w in 1 4; do
      cell="$b-$k-w$w"
      for i in 0 1; do
        "$BIN" shard --db "$TMP/db" --backend "$b" --kernel "$k" \
            --workers "$w" --index "$i" --of 2 --port 0 \
            > /dev/null 2> "$TMP/shard$i-$cell.log" &
        PIDS+=($!)
      done
      A0="$($CLIENT waitaddr "$TMP/shard0-$cell.log" 'serving ')"
      A1="$($CLIENT waitaddr "$TMP/shard1-$cell.log" 'serving ')"
      "$BIN" route --db "$TMP/db" --backend "$b" --kernel "$k" \
          --shards "$A0,$A1" --port 0 \
          > /dev/null 2> "$TMP/router-$cell.log" &
      ROUTER_PID=$!
      PIDS+=($ROUTER_PID)
      AR="$($CLIENT waitaddr "$TMP/router-$cell.log" 'routing ')"
      $CLIENT stream "$AR" "$TMP/reqs_shut.jsonl" "$TMP/routed-$cell.jsonl"
      wait "$ROUTER_PID"
      $CLIENT ask "$A0" "$SHUTDOWN" > /dev/null
      $CLIENT ask "$A1" "$SHUTDOWN" > /dev/null
      diff "$TMP/single-$b-$k.jsonl" "$TMP/routed-$cell.jsonl"
      grep -q ' requests (0 errors)' "$TMP/router-$cell.log"
      echo "ok $cell: routed responses byte-identical to single-process"
    done
  done
done

echo "== 2. chaos: SIGKILL a shard, typed error, data-dir recovery =="
DD="$TMP/shard0-data"
"$BIN" shard --db "$TMP/db" --data-dir "$DD" --index 0 --of 2 --port 0 \
    > /dev/null 2> "$TMP/chaos-shard0.log" &
S0_PID=$!
PIDS+=($S0_PID)
"$BIN" shard --db "$TMP/db" --index 1 --of 2 --port 0 \
    > /dev/null 2> "$TMP/chaos-shard1.log" &
PIDS+=($!)
A0="$($CLIENT waitaddr "$TMP/chaos-shard0.log" 'serving ')"
A1="$($CLIENT waitaddr "$TMP/chaos-shard1.log" 'serving ')"
"$BIN" route --db "$TMP/db" --shards "$A0,$A1" --port 0 \
    > /dev/null 2> "$TMP/chaos-router.log" &
PIDS+=($!)
AR="$($CLIENT waitaddr "$TMP/chaos-router.log" 'routing ')"
REQ="$(head -1 "$TMP/reqs.jsonl")"

before="$($CLIENT ask "$AR" "$REQ")"
echo "$before" | grep -q '"ok":true'

kill -9 "$S0_PID"
wait "$S0_PID" 2>/dev/null || true
during="$($CLIENT ask "$AR" "$REQ")"
echo "$during" | grep -q '"ok":false'
echo "$during" | grep -q 'route error: shard'
echo "ok chaos: dead shard answered as a typed route error"

# restart shard 0 from its data-dir alone, on the same port the router
# still dials
PORT0="${A0##*:}"
"$BIN" shard --data-dir "$DD" --index 0 --of 2 --port "$PORT0" \
    > /dev/null 2> "$TMP/chaos-shard0b.log" &
PIDS+=($!)
$CLIENT waitaddr "$TMP/chaos-shard0b.log" 'serving ' > /dev/null
grep -q 'recovering state from' "$TMP/chaos-shard0b.log"
after="$($CLIENT ask "$AR" "$REQ")"
test "$after" = "$before"
echo "ok chaos: restarted shard recovered; answer bit-identical to pre-kill"
$CLIENT ask "$AR" "$SHUTDOWN" > /dev/null
$CLIENT ask "$A0" "$SHUTDOWN" > /dev/null
$CLIENT ask "$A1" "$SHUTDOWN" > /dev/null

echo "== 3. replication: follower republishes the leader bit-identically =="
"$BIN" serve --db "$TMP/db" --port 0 --replicate-port 0 \
    --churn 0.05 --churn-steps 3 --delta-pause-ms 10 --seed 7 \
    > /dev/null 2> "$TMP/leader.log" &
PIDS+=($!)
AL="$($CLIENT waitaddr "$TMP/leader.log" 'serving ')"
ALR="$($CLIENT waitaddr "$TMP/leader.log" 'replicating ')"
"$BIN" serve --db "$TMP/db" --port 0 --follow "$ALR" \
    > /dev/null 2> "$TMP/follower.log" &
FOLLOWER_PID=$!
PIDS+=($FOLLOWER_PID)
AF="$($CLIENT waitaddr "$TMP/follower.log" 'serving ')"
# shutting the follower down waits internally for the replication
# stream to drain, so its summary always covers every leader epoch
$CLIENT ask "$AF" "$SHUTDOWN" > /dev/null
wait "$FOLLOWER_PID"
$CLIENT ask "$AL" "$SHUTDOWN" > /dev/null

leader_line="$(grep -o 'final epoch [0-9]* digest [0-9a-f]*' "$TMP/leader.log")"
follower_line="$(grep -o 'final epoch [0-9]* digest [0-9a-f]*' "$TMP/follower.log")"
echo "leader:   $leader_line"
echo "follower: $follower_line"
test -n "$leader_line"
test "$leader_line" = "$follower_line"
grep -q 'replica: applied epoch 3 of leader epoch 3 (lag 0, healthy)' \
    "$TMP/follower.log"
echo "ok replication: follower published the leader's epochs bit-identically"

echo "scaleout_smoke.sh: all gates passed"
