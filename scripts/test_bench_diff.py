#!/usr/bin/env python3
"""Synthetic-fixture tests for scripts/bench_diff.py.

Run directly (CI does, in bench-smoke):

    python3 scripts/test_bench_diff.py

Builds throwaway baseline/fresh directories and checks the diff's
verdicts, in particular the zero-baseline arithmetic: a 0.0 baseline
value used to divide by zero into a +/-inf% deviation and fail the run
on pure noise; it must now be judged against the epsilon floor.
"""

import json
import os
import subprocess
import sys
import tempfile

HERE = os.path.dirname(os.path.abspath(__file__))
DIFF = os.path.join(HERE, "bench_diff.py")

# every HEADLINES file must exist in both dirs or the diff fails, so
# fixtures write the full set and the test under scrutiny varies one
ALL_FILES = {
    "BENCH_scaling.json": [{"database": "uw", "strategy": "HYBRID", "workers": 2, "wall_s": 1.0}],
    "BENCH_planner.json": [{"database": "uw", "pre_fraction": 0.5, "workers": 2, "total_s": 2.0}],
    "BENCH_churn.json": [{"database": "uw", "churn_frac": 0.01, "workers": 2, "speedup": 3.0}],
    "BENCH_serve.json": [
        {"database": "uw", "workers": 2, "shards": 0, "throughput_rps": 1000.0}
    ],
    "BENCH_persist.json": [{"database": "uw", "workers": 2, "save_s": 0.1, "load_s": 0.1}],
    "BENCH_estimator.json": [
        {"database": "uw", "mode": "default", "q_p50": 1.0, "regret_saved_frac": 0.0}
    ],
    "BENCH_wcoj.json": [
        {"database": "tri_skew", "point": "R0+R1+R2", "speedup": 8.0}
    ],
    "BENCH_compress.json": [
        {"database": "tri_skew", "bytes_per_pair_ccsr": 5.0, "bytes_ratio": 3.2}
    ],
}


def write_dirs(tmp, base_overrides=None, fresh_overrides=None):
    base_dir = os.path.join(tmp, "base")
    fresh_dir = os.path.join(tmp, "fresh")
    os.makedirs(base_dir, exist_ok=True)
    os.makedirs(fresh_dir, exist_ok=True)
    for name, rows in ALL_FILES.items():
        brows = (base_overrides or {}).get(name, rows)
        frows = (fresh_overrides or {}).get(name, rows)
        with open(os.path.join(base_dir, name), "w") as f:
            json.dump({"provenance": "test", "rows": brows}, f)
        with open(os.path.join(fresh_dir, name), "w") as f:
            json.dump(frows, f)
    return base_dir, fresh_dir


def run_diff(base_dir, fresh_dir, env_extra=None):
    env = dict(os.environ)
    env.update(env_extra or {})
    proc = subprocess.run(
        [sys.executable, DIFF, base_dir, fresh_dir],
        capture_output=True,
        text=True,
        env=env,
    )
    return proc.returncode, proc.stdout


def check(name, cond, output):
    if cond:
        print(f"ok   {name}")
    else:
        print(f"FAIL {name}\n--- diff output ---\n{output}")
        sys.exit(1)


def main():
    with tempfile.TemporaryDirectory() as tmp:
        # identical runs pass
        code, out = run_diff(*write_dirs(tmp))
        check("identical runs pass", code == 0 and "RESULT: pass" in out, out)

    with tempfile.TemporaryDirectory() as tmp:
        # a genuine regression beyond tolerance fails
        fresh = {
            "BENCH_churn.json": [
                {"database": "uw", "churn_frac": 0.01, "workers": 2, "speedup": 1.0}
            ]
        }
        code, out = run_diff(*write_dirs(tmp, fresh_overrides=fresh))
        check("out-of-band metric fails", code == 1 and "FAIL" in out, out)

    with tempfile.TemporaryDirectory() as tmp:
        # THE BUG: a 0.0 baseline with sub-epsilon fresh noise used to
        # produce (f - 0)/0 -> +inf% and fail; with the epsilon floor it
        # is ordinary jitter
        base = {
            "BENCH_estimator.json": [
                {"database": "uw", "mode": "default", "q_p50": 1.0, "regret_saved_frac": 0.0}
            ]
        }
        fresh = {
            "BENCH_estimator.json": [
                {"database": "uw", "mode": "default", "q_p50": 1.0, "regret_saved_frac": 1e-5}
            ]
        }
        code, out = run_diff(*write_dirs(tmp, base, fresh))
        check("zero baseline + noise passes", code == 0, out)
        check("no infinite deviation printed", "inf" not in out, out)

    with tempfile.TemporaryDirectory() as tmp:
        # a real jump off a 0.0 baseline still fails under the floor
        base = {
            "BENCH_estimator.json": [
                {"database": "uw", "mode": "default", "q_p50": 1.0, "regret_saved_frac": 0.0}
            ]
        }
        fresh = {
            "BENCH_estimator.json": [
                {"database": "uw", "mode": "default", "q_p50": 1.0, "regret_saved_frac": 0.9}
            ]
        }
        code, out = run_diff(*write_dirs(tmp, base, fresh))
        check("zero baseline + real jump fails", code == 1, out)

    with tempfile.TemporaryDirectory() as tmp:
        # the floor is tunable: a huge epsilon waves the same jump through
        base = {
            "BENCH_estimator.json": [
                {"database": "uw", "mode": "default", "q_p50": 1.0, "regret_saved_frac": 0.0}
            ]
        }
        fresh = {
            "BENCH_estimator.json": [
                {"database": "uw", "mode": "default", "q_p50": 1.0, "regret_saved_frac": 0.9}
            ]
        }
        code, out = run_diff(
            *write_dirs(tmp, base, fresh), env_extra={"RELCOUNT_BENCH_EPSILON": "100"}
        )
        check("epsilon env var is honored", code == 0, out)

    with tempfile.TemporaryDirectory() as tmp:
        # seed baselines are record-only even when fresh rows differ wildly
        base = {"BENCH_wcoj.json": []}
        fresh = {
            "BENCH_wcoj.json": [
                {"database": "tri_skew", "point": "R0+R1+R2", "speedup": 0.001}
            ]
        }
        base_dir, fresh_dir = write_dirs(tmp, base, fresh)
        with open(os.path.join(base_dir, "BENCH_wcoj.json"), "w") as f:
            json.dump({"provenance": "seed", "rows": []}, f)
        code, out = run_diff(base_dir, fresh_dir)
        check("seed baseline is record-only", code == 0 and "record-only" in out, out)

    with tempfile.TemporaryDirectory() as tmp:
        # a vanished identity row fails
        fresh = {"BENCH_wcoj.json": []}
        code, out = run_diff(*write_dirs(tmp, fresh_overrides=fresh))
        check("vanished row fails", code == 1 and "vanished" in out, out)

    print("all bench_diff tests passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
